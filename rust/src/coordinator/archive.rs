//! The `.cpeft` **archive tier**: many encoded experts packed into one
//! local file, served as zero-copy [`Payload`] views.
//!
//! An archive (`CPAR`) is a fixed-offset index followed by the raw
//! encoded payloads of its members:
//!
//! ```text
//! magic "CPAR" | version u16 | flags u16 | n_members u32
//! [ id_len u32 | id bytes | offset u64 | len u64 | member_crc u32 ]*   (sorted by id)
//! index_crc u32                          (CRC-32 of everything above)
//! ..zero padding..
//! member payloads                        (each offset 64-byte aligned)
//! ```
//!
//! The index is CRC'd and bounds-checked with the same discipline as
//! the v2 `.cpeft` header: an implausible member count, an
//! out-of-bounds or overlapping region, an unsorted index, non-zero
//! padding, or trailing garbage is a structured `Err`, never a panic —
//! and every member access re-verifies the member CRC, so a flipped
//! bit inside a payload degrades that one expert to the remote-store
//! path instead of serving a wrong view.
//!
//! [`ArchiveTier`] keeps the whole file resident in one shared buffer —
//! a **simulated page cache** standing in for a real OS `mmap` (no
//! platform mmap without bringing in a dependency; the access pattern
//! and the zero-copy property are identical). `get` hands out a
//! [`Payload::mapped`] view of the member's byte range: the existing
//! readers ([`format::from_bytes`](crate::compeft::format::from_bytes) /
//! `from_bytes_par`) decode **in place** from the file image, so an
//! archive-resident expert costs zero heap copies of encoded bytes.
//! Member payloads start on [`MEMBER_ALIGN`]-byte file offsets, so the
//! v2 chunk frames inside each member keep a fixed alignment class and
//! parallel decode workers read the view exactly as they would a
//! standalone file.
//!
//! In the cache hierarchy the archive slots between host RAM and the
//! remote store: GPU ⊃ host ⊃ **archive** ⊃ remote.

use crate::compeft::format::crc32;
use crate::compeft::payload::{Payload, PayloadBacking};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::Registry;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

pub const ARCHIVE_MAGIC: &[u8; 4] = b"CPAR";
pub const ARCHIVE_VERSION: u16 = 1;
/// Member payloads start on this file-offset alignment, so chunk
/// frames inside a member keep the alignment class they would have in
/// a standalone `.cpeft` file.
pub const MEMBER_ALIGN: usize = 64;

const HEADER_LEN: usize = 12;
/// Smallest possible index entry: 4 (id_len) + 1 (id) + 8 + 8 + 4.
const MIN_ENTRY: usize = 25;

/// The resident file image — the simulated page cache every member
/// view borrows from.
struct PageCache(Vec<u8>);

impl PayloadBacking for PageCache {
    fn as_bytes(&self) -> &[u8] {
        &self.0
    }
}

#[derive(Clone, Debug)]
struct Member {
    id: String,
    offset: usize,
    len: usize,
    crc: u32,
}

/// Builds a `.cpeft` archive from (id, encoded bytes) members.
#[derive(Default)]
pub struct ArchiveBuilder {
    members: BTreeMap<String, Vec<u8>>,
}

impl ArchiveBuilder {
    pub fn new() -> ArchiveBuilder {
        ArchiveBuilder::default()
    }

    /// Add one member. Ids must be unique and non-empty; `bytes` are
    /// stored verbatim (whatever the expert's wire format).
    pub fn add(&mut self, id: &str, bytes: Vec<u8>) -> Result<()> {
        if id.is_empty() {
            bail!("archive member id must be non-empty");
        }
        if self.members.contains_key(id) {
            bail!("duplicate archive member id {id:?}");
        }
        self.members.insert(id.to_string(), bytes);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Serialize: index (sorted by id, CRC'd), then members at
    /// [`MEMBER_ALIGN`]-aligned offsets with zero padding between.
    pub fn to_bytes(&self) -> Vec<u8> {
        // Layout pass: where does each member land?
        let mut index_len = HEADER_LEN;
        for id in self.members.keys() {
            index_len += 4 + id.len() + 8 + 8 + 4;
        }
        let mut cursor = index_len + 4; // past index_crc
        // compeft-lint: allow(no-unchecked-wire-alloc) -- write path: sized from in-memory members
        let mut offsets = Vec::with_capacity(self.members.len());
        for bytes in self.members.values() {
            let off = cursor.next_multiple_of(MEMBER_ALIGN);
            offsets.push(off);
            cursor = off + bytes.len();
        }
        let total = cursor;

        // compeft-lint: allow(no-unchecked-wire-alloc) -- write path: sized from in-memory members
        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(ARCHIVE_MAGIC);
        out.extend_from_slice(&ARCHIVE_VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // flags
        out.extend_from_slice(&(self.members.len() as u32).to_le_bytes());
        for ((id, bytes), off) in self.members.iter().zip(&offsets) {
            out.extend_from_slice(&(id.len() as u32).to_le_bytes());
            out.extend_from_slice(id.as_bytes());
            out.extend_from_slice(&(*off as u64).to_le_bytes());
            out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            out.extend_from_slice(&crc32(bytes).to_le_bytes());
        }
        debug_assert_eq!(out.len(), index_len);
        let index_crc = crc32(&out);
        out.extend_from_slice(&index_crc.to_le_bytes());
        for (bytes, off) in self.members.values().zip(&offsets) {
            out.resize(*off, 0); // zero padding up to the aligned start
            out.extend_from_slice(bytes);
        }
        debug_assert_eq!(out.len(), total);
        out
    }

    /// Write the archive to `path`; returns the bytes written.
    pub fn write_to(&self, path: &Path) -> Result<u64> {
        let bytes = self.to_bytes();
        std::fs::write(path, &bytes)
            .with_context(|| format!("writing archive {}", path.display()))?;
        Ok(bytes.len() as u64)
    }
}

/// Pack every expert in `reg` (its stored checkpoint bytes, verbatim)
/// into one archive at `out`. Returns `(members, bytes_written)`.
pub fn build_from_registry(reg: &Registry, out: &Path) -> Result<(usize, u64)> {
    let mut b = ArchiveBuilder::new();
    for id in reg.ids() {
        let rec = reg.get(&id).with_context(|| format!("missing registry id {id:?}"))?;
        let bytes = std::fs::read(&rec.path)
            .with_context(|| format!("reading {} for archive member {id}", rec.path.display()))?;
        b.add(&id, bytes)?;
    }
    let written = b.write_to(out)?;
    Ok((b.len(), written))
}

/// A read-only, fully resident archive serving members as zero-copy
/// [`Payload`] views. Construction validates the whole index (magic,
/// version, CRC, bounds, ordering, padding); `get` re-verifies the
/// member CRC on every access so a corrupt payload region yields
/// `None` (degrade to the remote path) rather than a wrong view.
pub struct ArchiveTier {
    cache: Arc<PageCache>,
    /// Sorted by id (the index order), for binary search.
    index: Vec<Member>,
    metrics: Arc<Metrics>,
}

impl ArchiveTier {
    /// Load and validate an archive file.
    pub fn open(path: &Path, metrics: Arc<Metrics>) -> Result<ArchiveTier> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading archive {}", path.display()))?;
        ArchiveTier::from_bytes(bytes, metrics)
            .with_context(|| format!("opening archive {}", path.display()))
    }

    /// Validate an in-memory archive image (the page cache is this
    /// buffer; members become views of it).
    pub fn from_bytes(bytes: Vec<u8>, metrics: Arc<Metrics>) -> Result<ArchiveTier> {
        let len = bytes.len();
        if len < HEADER_LEN + 4 {
            bail!("archive too short ({len} bytes) for header + index CRC");
        }
        let magic = bytes.get(0..4).unwrap_or_default();
        if magic != ARCHIVE_MAGIC.as_slice() {
            bail!("bad archive magic {magic:?}");
        }
        // Fixed header reads, guarded by the `len >= HEADER_LEN + 4`
        // bail above.
        // compeft-lint: allow(no-panic-in-parse) -- header bytes 4..12 exist: len >= HEADER_LEN+4 was checked
        let version = u16::from_le_bytes([bytes[4], bytes[5]]);
        if version != ARCHIVE_VERSION {
            bail!("unsupported archive version {version}");
        }
        // compeft-lint: allow(no-panic-in-parse) -- header bytes 4..12 exist: len >= HEADER_LEN+4 was checked
        let flags = u16::from_le_bytes([bytes[6], bytes[7]]);
        if flags != 0 {
            bail!("unsupported archive flags {flags:#06x}");
        }
        // compeft-lint: allow(no-panic-in-parse) -- header bytes 4..12 exist: len >= HEADER_LEN+4 was checked
        let n_members = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
        // Plausibility bound before any allocation, v2-header style: an
        // index entry is at least MIN_ENTRY bytes.
        if n_members > (len - HEADER_LEN - 4) / MIN_ENTRY + 1 {
            bail!("implausible archive member count {n_members} for {len} bytes");
        }

        let mut pos = HEADER_LEN;
        let mut index = Vec::with_capacity(n_members);
        let read = |pos: usize, n: usize| -> Result<&[u8]> {
            // Index reads must stop short of the trailing index CRC.
            pos.checked_add(n)
                .filter(|&end| end <= len - 4)
                .and_then(|end| bytes.get(pos..end))
                .ok_or_else(|| anyhow!("archive index truncated at byte {pos}"))
        };
        for _ in 0..n_members {
            let id_len = u32::from_le_bytes(read(pos, 4)?.try_into()?) as usize;
            pos += 4;
            if id_len == 0 {
                bail!("archive member id must be non-empty");
            }
            let id = std::str::from_utf8(read(pos, id_len)?)
                .context("archive member id is not UTF-8")?
                .to_string();
            pos += id_len;
            let offset = u64::from_le_bytes(read(pos, 8)?.try_into()?);
            pos += 8;
            let mlen = u64::from_le_bytes(read(pos, 8)?.try_into()?);
            pos += 8;
            let crc = u32::from_le_bytes(read(pos, 4)?.try_into()?);
            pos += 4;
            let (offset, mlen) = (offset as usize, mlen as usize);
            index.push(Member { id, offset, len: mlen, crc });
        }
        let index_end = pos;
        let stored_crc = u32::from_le_bytes(
            bytes
                .get(index_end..index_end + 4)
                .ok_or_else(|| anyhow!("archive index truncated at byte {index_end}"))?
                .try_into()?,
        );
        if crc32(bytes.get(..index_end).unwrap_or_default()) != stored_crc {
            bail!("archive index CRC mismatch");
        }

        // Member regions: sorted ids, aligned, ascending, in bounds,
        // zero padding between them, no trailing garbage.
        let mut prev_end = index_end + 4;
        for w in index.windows(2) {
            if let [a, b] = w {
                if a.id >= b.id {
                    bail!("archive index not sorted by unique id ({:?} >= {:?})", a.id, b.id);
                }
            }
        }
        for m in &index {
            if m.offset % MEMBER_ALIGN != 0 {
                bail!("member {:?} offset {} not {MEMBER_ALIGN}-byte aligned", m.id, m.offset);
            }
            if m.offset < prev_end {
                bail!("member {:?} region overlaps the bytes before it", m.id);
            }
            let end = m
                .offset
                .checked_add(m.len)
                .filter(|&e| e <= len)
                .with_context(|| format!("member {:?} region out of bounds", m.id))?;
            // `prev_end <= m.offset <= end <= len` was established just
            // above, so this `get` cannot miss.
            if bytes.get(prev_end..m.offset).unwrap_or_default().iter().any(|&b| b != 0) {
                bail!("non-zero padding before member {:?}", m.id);
            }
            prev_end = end;
        }
        if prev_end != len {
            bail!("{} trailing bytes after the last archive member", len - prev_end);
        }

        Ok(ArchiveTier { cache: Arc::new(PageCache(bytes)), index, metrics })
    }

    /// Serve `id` as a zero-copy view of the resident file image.
    /// Verifies the member CRC on every access: a corrupt region
    /// returns `None` (counted as a failover + corrupt payload, like a
    /// bad stripe) so the caller degrades to the remote-store path.
    pub fn get(&self, id: &str) -> Option<Payload> {
        let i = self.index.binary_search_by(|m| m.id.as_str().cmp(id)).ok()?;
        let m = self.index.get(i)?;
        // Bounds were validated at open; `get` keeps the lookup
        // panic-free even so.
        let region = self.cache.0.get(m.offset..m.offset + m.len)?;
        if crc32(region) != m.crc {
            self.metrics.record_store_faults(0, 1, 1);
            return None;
        }
        let view = Payload::mapped(
            Arc::clone(&self.cache) as Arc<dyn PayloadBacking>,
            m.offset,
            m.len,
        )
        .ok()?;
        self.metrics.record_archive_hit(m.len as u64);
        Some(view)
    }

    pub fn contains(&self, id: &str) -> bool {
        self.index.binary_search_by(|m| m.id.as_str().cmp(id)).is_ok()
    }

    /// Member ids, in index (sorted) order.
    pub fn ids(&self) -> Vec<String> {
        self.index.iter().map(|m| m.id.clone()).collect()
    }

    /// `(offset, len)` of a member's byte range in the file image —
    /// for tests asserting alignment and in-place views.
    pub fn member_range(&self, id: &str) -> Option<(usize, usize)> {
        let i = self.index.binary_search_by(|m| m.id.as_str().cmp(id)).ok()?;
        let m = self.index.get(i)?;
        Some((m.offset, m.len))
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Bytes held resident by the simulated page cache (the whole file).
    pub fn resident_bytes(&self) -> u64 {
        self.cache.0.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_members() -> Vec<(String, Vec<u8>)> {
        // Irregular sizes on purpose: exercise padding and alignment.
        vec![
            ("expert/a".to_string(), (0..200u16).map(|i| (i % 251) as u8).collect()),
            ("expert/b".to_string(), vec![0xAB; 77]),
            ("zz".to_string(), (0..1000u16).map(|i| (i * 7 % 256) as u8).collect()),
        ]
    }

    fn build_sample() -> Vec<u8> {
        let mut b = ArchiveBuilder::new();
        for (id, bytes) in sample_members() {
            b.add(&id, bytes).unwrap();
        }
        b.to_bytes()
    }

    #[test]
    fn roundtrip_serves_aligned_in_place_views() {
        let image = build_sample();
        let m = Arc::new(Metrics::new());
        let tier = ArchiveTier::from_bytes(image, Arc::clone(&m)).unwrap();
        assert_eq!(tier.len(), 3);
        let mut viewed = 0u64;
        for (id, want) in sample_members() {
            let got = tier.get(&id).expect("member present");
            assert_eq!(&*got, &want[..], "bit-identical member {id}");
            let (off, len) = tier.member_range(&id).unwrap();
            assert_eq!(off % MEMBER_ALIGN, 0, "member {id} offset aligned");
            assert_eq!(len, want.len());
            viewed += len as u64;
        }
        // Views read in place: no copy, the slice points into the image.
        let (off, _) = tier.member_range("zz").unwrap();
        let v = tier.get("zz").unwrap();
        assert_eq!(
            v.as_slice().as_ptr(),
            unsafe { tier.cache.0.as_ptr().add(off) },
            "archive view reads straight out of the page cache"
        );
        let s = m.snapshot();
        assert_eq!(s.archive_hits, 4);
        assert_eq!(s.archive_bytes_viewed, viewed + v.len() as u64);
        assert_eq!(s.payload_copies, 0);
        assert!(tier.get("absent").is_none());
    }

    #[test]
    fn builder_rejects_duplicate_and_empty_ids() {
        let mut b = ArchiveBuilder::new();
        b.add("x", vec![1]).unwrap();
        assert!(b.add("x", vec![2]).is_err());
        assert!(b.add("", vec![3]).is_err());
        // Empty archive is valid and serves nothing.
        let tier =
            ArchiveTier::from_bytes(ArchiveBuilder::new().to_bytes(), Arc::new(Metrics::new()))
                .unwrap();
        assert!(tier.is_empty());
        assert!(tier.get("x").is_none());
    }

    /// Any single-bit flip anywhere in the file either fails `open`
    /// (index/padding damage) or makes exactly the damaged member
    /// return `None` — never a panic, never a wrong-expert view.
    #[test]
    fn bitflip_fuzz_never_panics_or_serves_wrong_bytes() {
        let image = build_sample();
        let members = sample_members();
        for i in 0..image.len() {
            let mut bad = image.clone();
            bad[i] ^= 1 << (i % 8);
            let m = Arc::new(Metrics::new());
            match ArchiveTier::from_bytes(bad, m) {
                Err(_) => {}
                Ok(tier) => {
                    for (id, want) in &members {
                        match tier.get(id) {
                            None => {}
                            Some(got) => assert_eq!(
                                &*got,
                                &want[..],
                                "flip at byte {i} served a wrong view of {id}"
                            ),
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn corrupt_member_degrades_and_counts_like_a_bad_stripe() {
        let image = build_sample();
        let m = Arc::new(Metrics::new());
        let (off, len) = {
            let tier = ArchiveTier::from_bytes(image.clone(), Arc::clone(&m)).unwrap();
            tier.member_range("expert/b").unwrap()
        };
        let mut bad = image;
        bad[off + len / 2] ^= 0x20;
        let tier = ArchiveTier::from_bytes(bad, Arc::clone(&m)).unwrap();
        assert!(tier.get("expert/b").is_none(), "corrupt member must not be served");
        // Undamaged members still serve.
        assert!(tier.get("expert/a").is_some());
        let s = m.snapshot();
        assert_eq!(s.failovers, 1);
        assert_eq!(s.corrupt_payloads, 1);
        assert_eq!(s.archive_hits, 1);
    }

    #[test]
    fn truncation_sweep_always_errs() {
        let image = build_sample();
        let cuts = [0, 1, 8, HEADER_LEN, HEADER_LEN + 5, image.len() / 2, image.len() - 1];
        for cut in cuts {
            let bad = image[..cut].to_vec();
            assert!(
                ArchiveTier::from_bytes(bad, Arc::new(Metrics::new())).is_err(),
                "truncation to {cut} bytes must be rejected"
            );
        }
        // Trailing garbage is rejected too, like the v2 container.
        let mut long = image.clone();
        long.push(0);
        assert!(ArchiveTier::from_bytes(long, Arc::new(Metrics::new())).is_err());
    }
}
