//! L3: the serving coordinator — the paper's communication story as a
//! running system.
//!
//! * [`registry`] — expert catalog (formats, encoded sizes)
//! * [`transport`] — simulated internet/disk/PCIe links over real bytes
//! * [`cache`] — byte-budgeted LRU tiers (GPU / CPU)
//! * [`loader`] — fetch → decode → materialize pipeline
//! * [`batcher`] — per-expert dynamic batching
//! * [`server`] — the engine thread + public [`server::Coordinator`] API
//! * [`metrics`] — latency histograms, swap/throughput counters

pub mod batcher;
pub mod cache;
pub mod loader;
pub mod metrics;
pub mod registry;
pub mod server;
pub mod transport;

pub use registry::{
    CompositionRecord, ExpertFormat, ExpertMethod, ExpertRecord, Registry,
};
pub use server::{Coordinator, CoordinatorConfig, EngineReport, Prediction};
pub use transport::{LinkSpec, SimLink};
