//! L3: the serving coordinator — the paper's communication story as a
//! running system.
//!
//! * [`registry`] — expert catalog (formats, encoded sizes)
//! * [`transport`] — simulated internet/disk/PCIe links over real bytes,
//!   with deterministic seeded fault injection ([`transport::FaultPlan`])
//! * [`store`] — sharded, replicated expert store: consistent-hash
//!   placement, striped parallel fetch, CRC-verified replica failover
//! * [`cache`] — byte-budgeted LRU tiers (GPU / CPU), with pinning
//! * [`archive`] — the `.cpeft` archive tier: one CRC-indexed file of
//!   packed experts served as zero-copy views of a simulated page cache
//! * [`loader`] — the fetch → decode → upload stages of a swap
//! * [`batcher`] — per-expert dynamic batching + queue-plan lookahead
//! * [`pipeline`] — prefetch-and-stage pipeline (background fetch+decode
//!   overlapped with batch execution)
//! * [`server`] — the engine thread + public [`server::Coordinator`] API
//! * [`admission`] — bounded-queue backpressure + deadline-aware load
//!   shedding at the submit door (pure, deterministic)
//! * [`metrics`] — latency histograms, swap/prefetch/throughput/failover
//!   counters

pub mod admission;
pub mod archive;
pub mod batcher;
pub mod cache;
pub mod loader;
pub mod metrics;
pub mod pipeline;
pub mod registry;
pub mod server;
pub mod store;
pub mod transport;

pub use admission::{admit, AdmissionConfig, AdmitDecision};
pub use archive::{build_from_registry, ArchiveBuilder, ArchiveTier};
pub use metrics::{RejectCounts, RejectReason};
pub use pipeline::{PrepareContext, PreparedExpert, Prefetcher, TakeOutcome, Templates};
pub use registry::{
    CompositionRecord, ExpertFormat, ExpertMethod, ExpertRecord, Registry,
};
pub use server::{Coordinator, CoordinatorConfig, EngineReport, Prediction};
pub use store::{ExpertStore, Placement, StoreConfig};
pub use transport::{Fault, FaultPlan, FaultSpec, LinkSpec, SimLink};
