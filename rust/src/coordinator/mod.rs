//! L3: the serving coordinator — the paper's communication story as a
//! running system.
//!
//! * [`registry`] — expert catalog (formats, encoded sizes)
//! * [`transport`] — simulated internet/disk/PCIe links over real bytes,
//!   with deterministic seeded fault injection ([`transport::FaultPlan`])
//! * [`store`] — sharded, replicated expert store: consistent-hash
//!   placement, striped parallel fetch, CRC-verified replica failover
//! * [`cache`] — byte-budgeted LRU tiers (GPU / CPU), with pinning
//! * [`archive`] — the `.cpeft` archive tier: one CRC-indexed file of
//!   packed experts served as zero-copy views of a simulated page cache
//! * [`loader`] — the fetch → decode → upload stages of a swap
//! * [`batcher`] — per-expert dynamic batching + queue-plan lookahead
//! * [`pipeline`] — prefetch-and-stage pipeline (background fetch+decode
//!   overlapped with batch execution)
//! * [`server`] — the engine thread + public [`server::Coordinator`] API
//! * [`admission`] — bounded-queue backpressure + deadline-aware load
//!   shedding at the submit door (pure, deterministic)
//! * [`metrics`] — latency histograms, swap/prefetch/throughput/failover
//!   counters
//!
//! # Lock-rank table
//!
//! Every long-lived mutex on the serve path is an
//! [`OrderedMutex`](crate::util::sync::OrderedMutex) carrying a static
//! rank, and a thread may only acquire locks in **strictly increasing
//! rank order**. The contract is enforced twice: debug builds keep a
//! thread-local stack of held ranks and panic on an out-of-order (or
//! re-entrant) acquisition, and the static analyzer (`cargo run -- lint`,
//! rule `lock-order`) reconstructs the whole-crate acquisition graph and
//! rejects any edge that descends the table — so a deadlock-shaped
//! change fails review even if no test happens to interleave it.
//!
//! The numeric ranks live in [`crate::util::sync::rank`] (the single
//! source of truth; `analysis::lockorder::LOCK_CLASSES` mirrors it and a
//! test pins the two together):
//!
//! | rank | lock               | guards                                      |
//! |-----:|--------------------|---------------------------------------------|
//! |   10 | `batcher.queues`   | per-expert queues + WFQ tenant state         |
//! |   20 | `pipeline.plan`    | prefetch plan (queue snapshot → worker work)  |
//! |   30 | `pipeline.staging` | staged decode results awaiting the engine    |
//! |   40 | `cache.cpu_tier`   | CPU-tier LRU over decoded payloads           |
//! |   50 | `transport.link`   | per-link virtual-time transfer state         |
//! |   60 | `pool.sender`      | thread-pool injector queue                   |
//! |   61 | `pool.receiver`    | thread-pool result collection                |
//! |   70 | `runtime.exec_cache` | compiled-executable memo                   |
//! |   80 | `metrics.inner`    | metrics registry (always innermost)          |
//!
//! Working rules: hold at most what you need; anything taken while a
//! lower-ranked guard is live must rank higher; `metrics.inner` is
//! deliberately last so any code path may record while holding any lock
//! (though the coordinator's paths record after release anyway).

pub mod admission;
pub mod archive;
pub mod batcher;
pub mod cache;
pub mod loader;
pub mod metrics;
pub mod pipeline;
pub mod registry;
pub mod server;
pub mod store;
pub mod transport;

pub use admission::{admit, AdmissionConfig, AdmitDecision};
pub use archive::{build_from_registry, ArchiveBuilder, ArchiveTier};
pub use metrics::{RejectCounts, RejectReason};
pub use pipeline::{PrepareContext, PreparedExpert, Prefetcher, TakeOutcome, Templates};
pub use registry::{
    CompositionRecord, ExpertFormat, ExpertMethod, ExpertRecord, Registry,
};
pub use server::{Coordinator, CoordinatorConfig, EngineReport, Prediction};
pub use store::{ExpertStore, Placement, StoreConfig};
pub use transport::{Fault, FaultPlan, FaultSpec, LinkSpec, SimLink};
