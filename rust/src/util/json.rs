//! Tiny JSON value model + serializer (serde is unavailable offline).
//!
//! Used to emit machine-readable bench rows and serving metrics. We only
//! need to *write* JSON; parsing is limited to the flat objects our own
//! tools emit (used by the bench harness to reload prior results).

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("set on non-object");
        }
        self
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (full recursive parser; sufficient for the
    /// artifacts our tools produce).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at {}", p.pos);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| anyhow::anyhow!("bad number {s:?}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => bail!("expected , or ] at {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected , or }} at {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::str("table1"))
            .set("acc", Json::num(63.45))
            .set("n", Json::num(8.0))
            .set("ok", Json::Bool(true))
            .set("rows", Json::Arr(vec![Json::num(1.0), Json::num(2.0)]));
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        let s = j.to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        if let Some(Json::Arr(xs)) = v.get("a") {
            assert_eq!(xs.len(), 3);
        } else {
            panic!("a not array");
        }
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }
}
