//! Minimal property-based testing driver (proptest is unavailable
//! offline).
//!
//! [`check`] runs a property over `cases` randomly generated inputs and
//! panics with the *seed and case index* of the first failure so the
//! case can be replayed deterministically:
//!
//! ```text
//! property failed: golomb roundtrip (seed=7, case=83): ...
//! ```
//!
//! There is no shrinking; generators are encouraged to bias toward small
//! and boundary inputs instead (see [`sizes`]).

use crate::util::rng::Pcg;

/// Run `prop` on `cases` generated inputs. `gen` receives a per-case RNG.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Pcg) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let seed = std::env::var("COMPEFT_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let mut rng = Pcg::new(seed, case as u64 + 1);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed: {name} (seed={seed}, case={case}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// A size ladder biased toward boundaries: empty, singleton, tiny,
/// non-power-of-two, and a few larger sizes. Useful for vector lengths.
pub fn sizes(rng: &mut Pcg) -> usize {
    const LADDER: [usize; 10] = [0, 1, 2, 3, 7, 8, 63, 64, 1000, 4097];
    if rng.next_f32() < 0.7 {
        LADDER[rng.range(0, LADDER.len())]
    } else {
        rng.range(0, 20_000)
    }
}

/// Worker-count ladder for the pool-parameterized equivalence suites.
///
/// Defaults to `{1, 2, 8}`; the `COMPEFT_TEST_WORKERS` environment
/// variable overrides it with a single count (`"8"`) or a comma list
/// (`"1,8"`) — the CI test matrix uses this to re-run every
/// pool-parameterized suite at fixed worker counts without editing the
/// tests.
pub fn pool_sizes() -> Vec<usize> {
    if let Ok(s) = std::env::var("COMPEFT_TEST_WORKERS") {
        let sizes: Vec<usize> = s
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .filter(|&n| (1..=64).contains(&n))
            .collect();
        if !sizes.is_empty() {
            return sizes;
        }
    }
    vec![1, 2, 8]
}

/// Assert two [`ParamSet`](crate::tensor::ParamSet)s are *bit*-identical
/// (names, shapes, and every f32's bit pattern — NaN-safe and
/// signed-zero-strict, unlike `PartialEq`). The shared teeth of the
/// engine equivalence suites (parallel-vs-serial, ternary-vs-dense).
pub fn assert_paramset_bit_identical(
    a: &crate::tensor::ParamSet,
    b: &crate::tensor::ParamSet,
    tag: &str,
) {
    assert_eq!(a.names(), b.names(), "{tag}: names");
    for (name, ta) in a.iter() {
        let tb = b.get(name).unwrap();
        assert_eq!(ta.shape, tb.shape, "{tag}/{name}: shape");
        for (i, (x, y)) in ta.data.iter().zip(&tb.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}/{name}[{i}]: {x} vs {y}");
        }
    }
}

/// Generate a task-vector-like f32 buffer: mostly near-zero gaussian
/// values with occasional large-magnitude entries, matching the
/// statistics reported in the paper's Table 7.
pub fn task_vector_like(rng: &mut Pcg, n: usize) -> Vec<f32> {
    let sigma = 10f64.powf(rng.next_f64() * 4.0 - 4.0); // 1e-4 .. 1e0
    (0..n)
        .map(|_| {
            let v = rng.normal_ms(0.0, sigma);
            if rng.next_f32() < 0.01 {
                (v * 30.0) as f32 // heavy-tail outliers
            } else {
                v as f32
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_for_true_property() {
        check("reverse twice is identity", 50, |rng| {
            let n = sizes(rng).min(100);
            (0..n).map(|_| rng.next_u32()).collect::<Vec<_>>()
        }, |xs| {
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            if ys == *xs { Ok(()) } else { Err("mismatch".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_panics_for_false_property() {
        check("always fails", 5, |rng| rng.next_u32(), |_| Err("nope".into()));
    }

    #[test]
    fn task_vector_like_has_near_zero_mean() {
        let mut rng = Pcg::seed(1);
        let v = task_vector_like(&mut rng, 50_000);
        let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        let sigma = crate::util::stats::std_f32(&v);
        assert!(mean.abs() < 5.0 * sigma / (v.len() as f64).sqrt() + 1e-6);
    }
}
