//! Minimal `.npy` / `.npz` reader–writer.
//!
//! The build-time Python side (training, AOT) exchanges tensors with the
//! Rust coordinator through NumPy's container formats: `.npy` (one
//! array) inside `.npz` (a zip archive). We implement the subset we
//! need — little-endian `f4`, `f8`, `i4`, `i8`, `u1` C-order arrays,
//! npy format version 1.0 — and write archives with `Stored`
//! compression so loads are a straight memcpy.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Seek, Write};
use std::path::Path;

/// Element type of an array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    F64,
    I32,
    I64,
    U8,
}

impl DType {
    pub fn descr(self) -> &'static str {
        match self {
            DType::F32 => "<f4",
            DType::F64 => "<f8",
            DType::I32 => "<i4",
            DType::I64 => "<i8",
            DType::U8 => "|u1",
        }
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 | DType::I64 => 8,
            DType::U8 => 1,
        }
    }

    fn from_descr(d: &str) -> Result<DType> {
        Ok(match d {
            "<f4" => DType::F32,
            "<f8" => DType::F64,
            "<i4" => DType::I32,
            "<i8" => DType::I64,
            "|u1" | "<u1" => DType::U8,
            other => bail!("unsupported npy dtype descr {other:?}"),
        })
    }
}

/// An n-dimensional array: shape + raw little-endian bytes.
#[derive(Clone, Debug)]
pub struct NpyArray {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl NpyArray {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn from_f32(shape: Vec<usize>, values: &[f32]) -> NpyArray {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        // compeft-lint: allow(no-unchecked-wire-alloc) -- sized from caller-held in-memory values, not wire data
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        NpyArray { dtype: DType::F32, shape, data }
    }

    pub fn from_i64(shape: Vec<usize>, values: &[i64]) -> NpyArray {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        // compeft-lint: allow(no-unchecked-wire-alloc) -- sized from caller-held in-memory values, not wire data
        let mut data = Vec::with_capacity(values.len() * 8);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        NpyArray { dtype: DType::I64, shape, data }
    }

    pub fn to_f32(&self) -> Result<Vec<f32>> {
        match self.dtype {
            DType::F32 => Ok(self
                .data
                .chunks_exact(4)
                // compeft-lint: allow(no-panic-in-parse) -- chunks_exact(4) yields exactly 4 bytes
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            DType::F64 => Ok(self
                .data
                .chunks_exact(8)
                .map(|c| {
                    // compeft-lint: allow(no-panic-in-parse) -- chunks_exact(8) yields exactly 8 bytes
                    f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                        as f32
                })
                .collect()),
            _ => bail!("array dtype {:?} is not float", self.dtype),
        }
    }

    pub fn to_i64(&self) -> Result<Vec<i64>> {
        match self.dtype {
            DType::I64 => Ok(self
                .data
                .chunks_exact(8)
                .map(|c| {
                    // compeft-lint: allow(no-panic-in-parse) -- chunks_exact(8) yields exactly 8 bytes
                    i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                })
                .collect()),
            DType::I32 => Ok(self
                .data
                .chunks_exact(4)
                // compeft-lint: allow(no-panic-in-parse) -- chunks_exact(4) yields exactly 4 bytes
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as i64)
                .collect()),
            DType::U8 => Ok(self.data.iter().map(|&b| b as i64).collect()),
            _ => bail!("array dtype {:?} is not integer", self.dtype),
        }
    }
}

// ---------------------------------------------------------------------------
// npy (single array)
// ---------------------------------------------------------------------------

const NPY_MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Serialize one array to npy v1.0 bytes.
pub fn write_npy_bytes(arr: &NpyArray) -> Vec<u8> {
    let shape_str = match arr.shape.as_slice() {
        [] => "()".to_string(),
        [d] => format!("({d},)"),
        ds => format!(
            "({})",
            ds.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let header = format!(
        "{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}",
        arr.dtype.descr(),
        shape_str
    );
    // Pad so that data begins at a multiple of 64 bytes (numpy convention).
    let unpadded = NPY_MAGIC.len() + 2 + 2 + header.len() + 1; // +1 newline
    let pad = (64 - unpadded % 64) % 64;
    let hlen = (header.len() + pad + 1) as u16;

    // compeft-lint: allow(no-unchecked-wire-alloc) -- write path: sized from in-memory data being serialized
    let mut out = Vec::with_capacity(unpadded + pad + arr.data.len());
    out.extend_from_slice(NPY_MAGIC);
    out.extend_from_slice(&[1u8, 0u8]); // version 1.0
    out.extend_from_slice(&hlen.to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    out.extend(std::iter::repeat(b' ').take(pad));
    out.push(b'\n');
    out.extend_from_slice(&arr.data);
    out
}

/// Parse npy v1.0/2.0 bytes into an array. Never panics on malformed
/// input: every header field is range-checked and the element count is
/// computed with overflow checks before it sizes anything.
pub fn read_npy_bytes(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.get(..6) != Some(NPY_MAGIC.as_slice()) {
        bail!("not an npy file (bad magic)");
    }
    let byte_at = |i: usize| -> Result<usize> {
        Ok(*bytes.get(i).ok_or_else(|| anyhow!("truncated npy header"))? as usize)
    };
    let major = byte_at(6)?;
    let (hlen, header_start) = match major {
        1 => (byte_at(8)? | (byte_at(9)? << 8), 10usize),
        2 | 3 => (
            byte_at(8)? | (byte_at(9)? << 8) | (byte_at(10)? << 16) | (byte_at(11)? << 24),
            12usize,
        ),
        v => bail!("unsupported npy major version {v}"),
    };
    let header_end = header_start
        .checked_add(hlen)
        .ok_or_else(|| anyhow!("npy header length overflows"))?;
    let header_bytes = bytes
        .get(header_start..header_end)
        .ok_or_else(|| anyhow!("truncated npy header"))?;
    let header = std::str::from_utf8(header_bytes).context("npy header not utf-8")?;

    let descr = extract_quoted(header, "descr")?;
    let dtype = DType::from_descr(&descr)?;
    if header.contains("'fortran_order': True") {
        bail!("fortran-order npy arrays are not supported");
    }
    let shape = parse_shape(header)?;

    // A hostile header can declare dims whose product wraps usize;
    // checked arithmetic turns that into an error before any slicing
    // or allocation is sized from it.
    let n = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow!("npy shape {shape:?} overflows element count"))?;
    let need = n
        .checked_mul(dtype.size())
        .ok_or_else(|| anyhow!("npy shape {shape:?} overflows byte count"))?;
    let data = bytes.get(header_end..).unwrap_or(&[]);
    let data = data.get(..need).ok_or_else(|| {
        anyhow!("npy data truncated: need {need} bytes, have {}", data.len())
    })?;
    Ok(NpyArray { dtype, shape, data: data.to_vec() })
}

fn extract_quoted(header: &str, key: &str) -> Result<String> {
    let pat = format!("'{key}':");
    let at = header.find(&pat).ok_or_else(|| anyhow!("npy header missing {key}"))?;
    // `find` returns in-range indices, so these `get`s cannot miss; the
    // empty-string fallback keeps the path index-free anyway.
    let rest = header.get(at + pat.len()..).unwrap_or("");
    let q1 = rest.find('\'').ok_or_else(|| anyhow!("bad {key} value"))?;
    let rest = rest.get(q1 + 1..).unwrap_or("");
    let q2 = rest.find('\'').ok_or_else(|| anyhow!("bad {key} value"))?;
    Ok(rest.get(..q2).unwrap_or("").to_string())
}

fn parse_shape(header: &str) -> Result<Vec<usize>> {
    let at = header.find("'shape':").ok_or_else(|| anyhow!("npy header missing shape"))?;
    let rest = header.get(at..).unwrap_or("");
    let open = rest.find('(').ok_or_else(|| anyhow!("bad shape"))?;
    let close = rest.find(')').ok_or_else(|| anyhow!("bad shape"))?;
    // `)` before `(` — e.g. a header like "'shape': ) ("— must be a
    // parse error, not a backwards slice panic.
    let inner = rest.get(open + 1..close).ok_or_else(|| anyhow!("bad shape"))?;
    let mut shape = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        shape.push(part.parse::<usize>().with_context(|| format!("bad dim {part:?}"))?);
    }
    Ok(shape)
}

// ---------------------------------------------------------------------------
// npz (zip of npy)
// ---------------------------------------------------------------------------

/// Read all arrays in an `.npz` archive, keyed by entry name without the
/// `.npy` suffix.
pub fn read_npz(path: &Path) -> Result<BTreeMap<String, NpyArray>> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_npz_from(file).with_context(|| format!("parse {}", path.display()))
}

/// Read arrays from any seekable zip stream.
pub fn read_npz_from<R: Read + Seek>(reader: R) -> Result<BTreeMap<String, NpyArray>> {
    let mut zip = zip::ZipArchive::new(reader).context("open zip")?;
    let mut out = BTreeMap::new();
    for i in 0..zip.len() {
        let mut entry = zip.by_index(i).context("zip entry")?;
        let name = entry.name().trim_end_matches(".npy").to_string();
        // Grow with the bytes actually read: pre-sizing from the
        // entry's *declared* size would let a hostile archive demand an
        // arbitrarily large allocation before a single byte arrives.
        let mut bytes = Vec::new();
        entry.read_to_end(&mut bytes)?;
        let arr =
            read_npy_bytes(&bytes).with_context(|| format!("entry {name:?}"))?;
        out.insert(name, arr);
    }
    Ok(out)
}

/// Write arrays to an `.npz` archive (stored, uncompressed entries).
pub fn write_npz(path: &Path, arrays: &BTreeMap<String, NpyArray>) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut zip = zip::ZipWriter::new(file);
    let opts = zip::write::FileOptions::default()
        .compression_method(zip::CompressionMethod::Stored);
    for (name, arr) in arrays {
        zip.start_file(format!("{name}.npy"), opts)?;
        zip.write_all(&write_npy_bytes(arr))?;
    }
    zip.finish()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn npy_roundtrip_f32() {
        let arr = NpyArray::from_f32(vec![2, 3], &[1.0, -2.5, 3.25, 0.0, 7.5, -0.125]);
        let bytes = write_npy_bytes(&arr);
        let back = read_npy_bytes(&bytes).unwrap();
        assert_eq!(back.dtype, DType::F32);
        assert_eq!(back.shape, vec![2, 3]);
        assert_eq!(back.to_f32().unwrap(), arr.to_f32().unwrap());
    }

    #[test]
    fn npy_roundtrip_scalar_and_1d() {
        let s = NpyArray::from_f32(vec![], &[42.0]);
        let back = read_npy_bytes(&write_npy_bytes(&s)).unwrap();
        assert_eq!(back.shape, Vec::<usize>::new());
        assert_eq!(back.to_f32().unwrap(), vec![42.0]);

        let v = NpyArray::from_i64(vec![4], &[1, -2, 3, 9_000_000_000]);
        let back = read_npy_bytes(&write_npy_bytes(&v)).unwrap();
        assert_eq!(back.shape, vec![4]);
        assert_eq!(back.to_i64().unwrap(), vec![1, -2, 3, 9_000_000_000]);
    }

    #[test]
    fn npy_data_alignment_is_64() {
        let arr = NpyArray::from_f32(vec![1], &[1.0]);
        let bytes = write_npy_bytes(&arr);
        assert_eq!((bytes.len() - 4) % 64, 0, "header must pad to 64B");
    }

    #[test]
    fn npz_roundtrip_via_memory() {
        let mut arrays = BTreeMap::new();
        arrays.insert("w".to_string(), NpyArray::from_f32(vec![2, 2], &[1., 2., 3., 4.]));
        arrays.insert("ids".to_string(), NpyArray::from_i64(vec![3], &[7, 8, 9]));

        let mut buf = Vec::new();
        {
            let mut zipw = zip::ZipWriter::new(Cursor::new(&mut buf));
            let opts = zip::write::FileOptions::default()
                .compression_method(zip::CompressionMethod::Stored);
            for (name, arr) in &arrays {
                zipw.start_file(format!("{name}.npy"), opts).unwrap();
                zipw.write_all(&write_npy_bytes(arr)).unwrap();
            }
            zipw.finish().unwrap();
        }
        let back = read_npz_from(Cursor::new(&buf)).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["w"].to_f32().unwrap(), vec![1., 2., 3., 4.]);
        assert_eq!(back["ids"].to_i64().unwrap(), vec![7, 8, 9]);
    }

    #[test]
    fn npz_roundtrip_via_files() {
        let dir = std::env::temp_dir().join("compeft_npz_test");
        let path = dir.join("t.npz");
        let mut arrays = BTreeMap::new();
        arrays.insert(
            "a/b".to_string(),
            NpyArray::from_f32(vec![3], &[0.5, -0.5, 2.0]),
        );
        write_npz(&path, &arrays).unwrap();
        let back = read_npz(&path).unwrap();
        assert_eq!(back["a/b"].to_f32().unwrap(), vec![0.5, -0.5, 2.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_npy_bytes(b"not an npy").is_err());
        assert!(read_npy_bytes(b"").is_err());
    }

    /// Valid npy v1 framing around an arbitrary header string.
    fn v1_with_header(header: &str) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(NPY_MAGIC);
        out.extend_from_slice(&[1u8, 0u8]);
        let h = format!("{header}\n");
        out.extend_from_slice(&(h.len() as u16).to_le_bytes());
        out.extend_from_slice(h.as_bytes());
        out
    }

    #[test]
    fn hostile_shape_product_errs_instead_of_wrapping() {
        // 2^28 * 2^28 * 2^28 = 2^84 wraps a 64-bit element count.
        let h = "{'descr': '<f8', 'fortran_order': False, \
                 'shape': (268435456, 268435456, 268435456), }";
        let err = read_npy_bytes(&v1_with_header(h)).unwrap_err();
        assert!(format!("{err:#}").contains("overflows"), "{err:#}");
        // 2^61 elements fit, but * 8 bytes wraps to a byte count of 0 —
        // the old unchecked multiply accepted this as an empty array of
        // 2^61 declared elements.
        let h = "{'descr': '<f8', 'fortran_order': False, \
                 'shape': (2305843009213693952,), }";
        let err = read_npy_bytes(&v1_with_header(h)).unwrap_err();
        assert!(format!("{err:#}").contains("overflows"), "{err:#}");
    }

    #[test]
    fn reversed_shape_parens_err_not_panic() {
        let h = "{'descr': '<f4', 'fortran_order': False, 'shape': ) (, }";
        assert!(read_npy_bytes(&v1_with_header(h)).is_err());
    }

    #[test]
    fn truncated_headers_err_not_panic() {
        // v1 with a header-length field promising more than exists.
        let mut v1 = Vec::new();
        v1.extend_from_slice(NPY_MAGIC);
        v1.extend_from_slice(&[1u8, 0u8]);
        v1.extend_from_slice(&u16::MAX.to_le_bytes());
        assert!(read_npy_bytes(&v1).is_err());
        // v2 cut off in the middle of its 4-byte length field.
        let mut v2 = Vec::new();
        v2.extend_from_slice(NPY_MAGIC);
        v2.extend_from_slice(&[2u8, 0u8]);
        v2.extend_from_slice(&[0xFF, 0xFF]);
        assert!(read_npy_bytes(&v2).is_err());
    }

    #[test]
    fn lying_zip_entry_size_cannot_force_allocation() {
        let mut arrays = BTreeMap::new();
        arrays.insert("w".to_string(), NpyArray::from_f32(vec![2], &[1.0, 2.0]));
        let mut buf = Vec::new();
        {
            let mut zipw = zip::ZipWriter::new(Cursor::new(&mut buf));
            let opts = zip::write::FileOptions::default()
                .compression_method(zip::CompressionMethod::Stored);
            for (name, arr) in &arrays {
                zipw.start_file(format!("{name}.npy"), opts).unwrap();
                zipw.write_all(&write_npy_bytes(arr)).unwrap();
            }
            zipw.finish().unwrap();
        }
        // Inflate the declared *uncompressed* size to ~4 GiB in both the
        // local header (offset 22) and the central directory (offset 24
        // past its signature). The stored data is untouched, so a reader
        // that sizes buffers from bytes actually read still succeeds —
        // one that trusts the declared size would allocate 4 GiB first.
        let lie = 0xFFFF_FFFEu32.to_le_bytes();
        buf[22..26].copy_from_slice(&lie);
        let cd = buf
            .windows(4)
            .position(|w| w == [0x50, 0x4b, 0x01, 0x02])
            .expect("central directory signature");
        buf[cd + 24..cd + 28].copy_from_slice(&lie);
        let back = read_npz_from(Cursor::new(&buf)).unwrap();
        assert_eq!(back["w"].to_f32().unwrap(), vec![1.0, 2.0]);
    }
}
