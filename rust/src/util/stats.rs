//! Descriptive statistics used throughout the library: task-vector
//! statistics (paper Table 7), latency summaries (paper Table 5), and
//! the bench harness (mean ± std rows).

/// Mean of a slice (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Mean of f32 data computed in f64.
pub fn mean_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Running first/second moments (Welford), mergeable via the parallel
/// update of Chan, Golub & LeVeque (1983).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Moments {
    pub n: u64,
    pub mean: f64,
    /// Sum of squared deviations from the mean (`M2` in Welford's
    /// notation); population variance is `m2 / n`.
    pub m2: f64,
}

impl Moments {
    /// Welford accumulation over one contiguous block.
    pub fn of(xs: &[f32]) -> Moments {
        let mut n = 0u64;
        let mut mean = 0.0f64;
        let mut m2 = 0.0f64;
        for &x in xs {
            n += 1;
            let x = x as f64;
            let d = x - mean;
            mean += d / n as f64;
            m2 += d * (x - mean);
        }
        Moments { n, mean, m2 }
    }

    /// Combine two disjoint blocks' moments. Not bit-associative (float
    /// rounding), so callers that need determinism must merge in a fixed
    /// order — see [`blocked_moments`].
    pub fn merge(self, other: Moments) -> Moments {
        if self.n == 0 {
            return other;
        }
        if other.n == 0 {
            return self;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * (other.n as f64 / n as f64);
        let m2 = self.m2
            + other.m2
            + d * d * (self.n as f64 * other.n as f64 / n as f64);
        Moments { n, mean, m2 }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.m2 / self.n as f64).max(0.0).sqrt()
        }
    }
}

/// Fixed block size for the chunked `σ(τ)` computation. The block size
/// (not the pool's work division) defines the floating-point merge tree,
/// so serial and parallel paths agree bit-for-bit at any worker count
/// and any engine chunk size.
pub const MOMENT_BLOCK: usize = 1 << 16;

/// Per-[`MOMENT_BLOCK`] Welford moments folded left-to-right. This is
/// the canonical merge order; [`par_blocked_moments`] reproduces it
/// exactly.
pub fn blocked_moments(xs: &[f32]) -> Moments {
    let mut acc = Moments::default();
    for block in xs.chunks(MOMENT_BLOCK) {
        acc = acc.merge(Moments::of(block));
    }
    acc
}

/// `σ(τ)` via [`blocked_moments`] — the engine's deterministic σ.
pub fn blocked_std_f32(xs: &[f32]) -> f64 {
    blocked_moments(xs).std()
}

/// Parallel [`blocked_moments`]: per-block Welford on the pool, merged
/// left-to-right on the caller thread. Bit-identical to the serial fold
/// because the block decomposition and merge order are fixed.
pub fn par_blocked_moments(xs: &[f32], pool: &crate::util::pool::ThreadPool) -> Moments {
    let blocks: Vec<&[f32]> = xs.chunks(MOMENT_BLOCK.max(1)).collect();
    let partials = pool.scoped_map(blocks, Moments::of);
    partials.into_iter().fold(Moments::default(), Moments::merge)
}

/// Parallel [`blocked_std_f32`].
pub fn par_blocked_std_f32(xs: &[f32], pool: &crate::util::pool::ThreadPool) -> f64 {
    par_blocked_moments(xs, pool).std()
}

/// Population std of f32 data computed in f64. This is the `σ(τ)` used
/// by ComPEFT's quantization step (Algorithm 1).
pub fn std_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean_f32(xs);
    let var = xs.iter().map(|&x| (x as f64 - m) * (x as f64 - m)).sum::<f64>()
        / xs.len() as f64;
    var.sqrt()
}

/// Quantile by linear interpolation on a *sorted* slice, q in [0,1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Summary of a sample: n, mean, std, min, p50, p95, p99, max.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: std(xs),
            min: s[0],
            p50: quantile_sorted(&s, 0.50),
            p95: quantile_sorted(&s, 0.95),
            p99: quantile_sorted(&s, 0.99),
            max: *s.last().unwrap(),
        }
    }
}

/// Streaming latency histogram with logarithmic buckets from 1µs to
/// ~100s. Used by the coordinator's metrics so the hot path only does a
/// bucket increment (no allocation, no sort).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    sum_us: f64,
    max_us: f64,
}

const HIST_BUCKETS: usize = 160; // 8 buckets per decade over 1e0..1e8 µs

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram { counts: vec![0; HIST_BUCKETS], total: 0, sum_us: 0.0, max_us: 0.0 }
    }

    fn bucket(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        let b = (us.log10() * 20.0) as usize; // 20 buckets/decade
        b.min(HIST_BUCKETS - 1)
    }

    pub fn record_us(&mut self, us: f64) {
        self.counts[Self::bucket(us)] += 1;
        self.total += 1;
        self.sum_us += us;
        if us > self.max_us {
            self.max_us = us;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Approximate quantile (bucket upper edge).
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return 10f64.powf((i + 1) as f64 / 20.0);
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn moments_match_two_pass_std() {
        let xs: Vec<f32> = (0..10_000).map(|i| ((i * 37) % 101) as f32 - 50.0).collect();
        let m = Moments::of(&xs);
        assert_eq!(m.n, xs.len() as u64);
        assert!((m.std() - std_f32(&xs)).abs() < 1e-9);
        let b = blocked_moments(&xs);
        assert!((b.std() - std_f32(&xs)).abs() < 1e-9);
    }

    #[test]
    fn moments_merge_equals_whole() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        let (a, b) = xs.split_at(317);
        let merged = Moments::of(a).merge(Moments::of(b));
        let whole = Moments::of(&xs);
        assert_eq!(merged.n, whole.n);
        assert!((merged.mean - whole.mean).abs() < 1e-12);
        assert!((merged.std() - whole.std()).abs() < 1e-12);
        // Identity element on both sides.
        assert_eq!(Moments::default().merge(whole), whole);
        assert_eq!(whole.merge(Moments::default()), whole);
    }

    #[test]
    fn par_blocked_std_bit_identical_to_serial() {
        use crate::util::pool::ThreadPool;
        use crate::util::rng::Pcg;
        let mut rng = Pcg::seed(20);
        // Spans several MOMENT_BLOCKs plus a ragged tail.
        let n = 3 * MOMENT_BLOCK + 12_345;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal_ms(0.0, 0.01) as f32).collect();
        let serial = blocked_std_f32(&xs);
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            let par = par_blocked_std_f32(&xs, &pool);
            assert_eq!(serial.to_bits(), par.to_bits(), "workers={workers}");
        }
        assert_eq!(blocked_std_f32(&[]), 0.0);
    }

    #[test]
    fn std_f32_matches_f64_path() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32) * 0.1 - 5.0).collect();
        let xs64: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
        assert!((std_f32(&xs) - std(&xs64)).abs() < 1e-6);
    }

    #[test]
    fn quantiles_interpolate() {
        let s: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert!((quantile_sorted(&s, 0.5) - 50.0).abs() < 1e-9);
        assert!((quantile_sorted(&s, 0.95) - 95.0).abs() < 1e-9);
        assert!((quantile_sorted(&s, 0.0) - 0.0).abs() < 1e-9);
        assert!((quantile_sorted(&s, 1.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn summary_consistent() {
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 1000);
        assert!((s.mean - 500.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 1000.0);
        assert!((s.p50 - 500.5).abs() < 1.0);
    }

    #[test]
    fn histogram_quantiles_roughly_correct() {
        let mut h = LogHistogram::new();
        for i in 1..=10_000u64 {
            h.record_us(i as f64); // uniform 1µs..10ms
        }
        let p50 = h.quantile_us(0.5);
        assert!(p50 > 3_000.0 && p50 < 8_000.0, "p50={p50}");
        assert_eq!(h.count(), 10_000);
        assert!((h.mean_us() - 5000.5).abs() < 1.0);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record_us(10.0);
        b.record_us(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.max_us() >= 1000.0);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(std_f32(&[]), 0.0);
        let h = LogHistogram::new();
        assert_eq!(h.quantile_us(0.5), 0.0);
    }
}
