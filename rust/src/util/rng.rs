//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we implement a small,
//! well-understood generator: PCG-XSH-RR 64/32 (O'Neill 2014) with a
//! 64-bit state expansion for `next_u64`. All stochastic components of
//! the library (workload generators, ES optimizer, property tests,
//! simulated links) take an explicit [`Pcg`] so every run is
//! reproducible from a seed.

/// splitmix64 finalizer: a cheap, well-mixed u64 → u64 bijection.
/// Used to turn structured keys (node ids, hash folds) into uniform
/// points for consistent hashing and fault-plan decisions.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Seeded FNV-1a over a byte string. The one key-fold shared by the
/// store's consistent-hash placement and the transport's fault plan —
/// fault determinism ("same seed → same failover sequence") depends on
/// this exact function, so it lives in one place.
pub fn fnv1a_64(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64 ^ seed;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

/// PCG-XSH-RR 64/32 generator.
///
/// Not cryptographic. Deterministic across platforms (pure integer ops).
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and a stream id. Different stream
    /// ids yield independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with stream 0.
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Next 32 uniformly distributed bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly distributed bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform float in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        // 24 random mantissa bits.
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform double in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) with Lemire rejection to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal sample (Box–Muller, cached second value omitted
    /// for simplicity — throughput is not a bottleneck for our uses).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean and std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Geometric sample: number of Bernoulli(p) failures before the first
    /// success (support {0,1,2,...}). Used to generate gap-distributed
    /// sparse positions matching the Golomb-coding model.
    pub fn geometric(&mut self, p: f64) -> u64 {
        assert!(p > 0.0 && p <= 1.0);
        if p >= 1.0 {
            return 0;
        }
        let u = self.next_f64().max(1e-300);
        (u.ln() / (1.0 - p).ln()).floor() as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Zipf-distributed sampler over {0, .., n-1} with exponent `s`.
///
/// Used for request traces in the serving benchmarks (a few hot experts,
/// a long tail), matching multi-tenant serving workloads.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::seed(7);
        let mut b = Pcg::seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg::new(7, 1);
        let mut b = Pcg::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut rng = Pcg::seed(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_respects_bound_and_covers() {
        let mut rng = Pcg::seed(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seed(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn geometric_mean_matches() {
        let mut rng = Pcg::seed(5);
        let p = 0.05f64;
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| rng.geometric(p) as f64).sum::<f64>() / n as f64;
        let expect = (1.0 - p) / p; // 19
        assert!((mean - expect).abs() / expect < 0.05, "mean={mean} expect={expect}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seed(9);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Pcg::seed(2);
        let z = Zipf::new(16, 1.1);
        let mut counts = [0usize; 16];
        for _ in 0..10_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[8], "head should dominate: {counts:?}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg::seed(4);
        let idx = rng.sample_indices(50, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }
}
