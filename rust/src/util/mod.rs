//! Foundation substrates implemented in-repo because the offline crate
//! set is limited to `xla` + `anyhow` + `zip` (see DESIGN.md §8):
//! deterministic RNG, bit I/O, stats, npy/npz interchange, a worker
//! pool, CLI parsing, JSON, a property-test driver, and a bench harness.

pub mod bench;
pub mod bitio;
pub mod cli;
pub mod json;
pub mod npz;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
