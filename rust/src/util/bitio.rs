//! Bit-granular I/O used by the Golomb/Rice entropy coder.
//!
//! Bits are written MSB-first within each byte so encoded streams are
//! byte-order independent and easy to inspect in hex dumps.
//!
//! # Word-at-a-time reads
//!
//! The bit-at-a-time readers ([`BitReader::get_bit`],
//! [`BitReader::get_bits`], [`BitReader::get_unary`]) pay a shift, a
//! mask, and a bounds check per *bit*. Decode hot loops instead use the
//! peek/consume pair:
//!
//! * [`BitReader::peek_word`] returns up to 64 upcoming bits MSB-aligned
//!   in a `u64` window plus the number of valid top bits. Bits below the
//!   valid region are guaranteed zero, so a unary scan via
//!   `leading_zeros` on the inverted window self-terminates at the
//!   window edge instead of reading stale data.
//! * [`BitReader::consume`] advances the position once per decoded
//!   symbol (or group of fields), not once per bit.
//!
//! A caller decodes a whole Rice codeword (unary quotient, terminator,
//! `b`-bit remainder, sign bit) from one peeked window with shifts and
//! masks — no per-bit branches — and falls back to the bit-at-a-time
//! loop only when the codeword straddles the window edge or the stream
//! tail. The bit-at-a-time loop stays authoritative: it is the
//! differential-test oracle the word path is checked against.

/// Append-only bit writer backed by a `Vec<u8>`.
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Number of valid bits in the last byte (0 means last byte is full
    /// or buffer is empty).
    partial: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bytes), partial: 0 }
    }

    /// Total number of bits written so far.
    pub fn bit_len(&self) -> u64 {
        if self.partial == 0 {
            self.buf.len() as u64 * 8
        } else {
            (self.buf.len() as u64 - 1) * 8 + self.partial as u64
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        if self.partial == 0 {
            self.buf.push(0);
        }
        if bit {
            let idx = self.buf.len() - 1;
            self.buf[idx] |= 1 << (7 - self.partial);
        }
        self.partial = (self.partial + 1) % 8;
    }

    /// Write the lowest `n` bits of `v`, MSB first. `n <= 64`.
    #[inline]
    pub fn put_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        for i in (0..n).rev() {
            self.put_bit((v >> i) & 1 == 1);
        }
    }

    /// Write `n` consecutive one-bits followed by a zero (unary code).
    #[inline]
    pub fn put_unary(&mut self, n: u64) {
        for _ in 0..n {
            self.put_bit(true);
        }
        self.put_bit(false);
    }

    /// Append every bit of `other`, as if its `put_*` calls had been
    /// replayed on `self`. Byte-aligned appends are a memcpy; unaligned
    /// appends merge with two shifts per byte (O(bytes), not O(bits)) —
    /// cheap enough that parallel encoders can build per-chunk
    /// substreams and still emit a byte stream identical to the serial
    /// writer's without the merge eating the speedup.
    pub fn append(&mut self, other: &BitWriter) {
        let bits = other.bit_len();
        let full_bytes = (bits / 8) as usize;
        let rem = (bits % 8) as u32;
        if self.partial == 0 {
            self.buf.extend_from_slice(&other.buf[..full_bytes]);
        } else {
            // Last byte of self holds `partial` valid MSBs; each full
            // byte of `other` fills its low bits and spills the rest
            // into a fresh byte. `partial` is unchanged by whole bytes.
            let shift = self.partial;
            for &byte in &other.buf[..full_bytes] {
                let idx = self.buf.len() - 1;
                self.buf[idx] |= byte >> shift;
                self.buf.push(byte << (8 - shift));
            }
        }
        if rem > 0 {
            let last = other.buf[full_bytes];
            self.put_bits((last >> (8 - rem)) as u64, rem);
        }
    }

    /// Finish and return the byte buffer (zero-padded in the last byte).
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// Bit reader over a byte slice, MSB-first (matches [`BitWriter`]).
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: u64, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    pub fn bits_remaining(&self) -> u64 {
        self.buf.len() as u64 * 8 - self.pos
    }

    pub fn bit_pos(&self) -> u64 {
        self.pos
    }

    /// Jump to an absolute bit position. Positions up to (and including)
    /// the end of the buffer are valid — reads from there return `None`.
    /// Parallel decoders seek each worker to a frame boundary recorded
    /// by the encoder, then read forward as usual.
    pub fn seek(&mut self, bit: u64) -> Option<()> {
        if bit > self.buf.len() as u64 * 8 {
            return None;
        }
        self.pos = bit;
        Some(())
    }

    /// Read one bit; `None` at end of stream.
    #[inline]
    pub fn get_bit(&mut self) -> Option<bool> {
        let byte = (self.pos / 8) as usize;
        if byte >= self.buf.len() {
            return None;
        }
        let off = 7 - (self.pos % 8) as u32;
        self.pos += 1;
        Some((self.buf[byte] >> off) & 1 == 1)
    }

    /// Read `n` bits MSB-first into the low bits of a u64.
    #[inline]
    pub fn get_bits(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 64);
        if self.bits_remaining() < n as u64 {
            return None;
        }
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.get_bit()? as u64;
        }
        Some(v)
    }

    /// Read a unary code: count of one-bits before the terminating zero.
    #[inline]
    pub fn get_unary(&mut self) -> Option<u64> {
        let mut n = 0u64;
        loop {
            match self.get_bit()? {
                true => n += 1,
                false => return Some(n),
            }
        }
    }

    /// Peek up to 64 upcoming bits without consuming them, MSB-aligned:
    /// the bit at the current position sits in bit 63 of the returned
    /// window. Returns `(window, avail)` where the top `avail` bits are
    /// real stream bits and **every bit below them is zero** — so
    /// `(!window).leading_zeros()` (a unary scan) can never run past
    /// the valid region into stale data. At or past the end of the
    /// buffer the window is empty: `(0, 0)`.
    ///
    /// `avail` is at most `64 - (pos % 8)` (the window is assembled
    /// from at most 8 whole bytes), and at most [`bits_remaining`]
    /// near the stream tail. Like the bit-at-a-time readers, the
    /// window includes any zero-padding bits inside the final byte.
    ///
    /// [`bits_remaining`]: BitReader::bits_remaining
    #[inline]
    pub fn peek_word(&self) -> (u64, u32) {
        let byte = (self.pos / 8) as usize;
        let bit = (self.pos % 8) as u32;
        if byte + 8 <= self.buf.len() {
            // 8 whole bytes cover the window; shifting out the `bit`
            // already-consumed MSBs zero-fills the low end.
            let w = u64::from_be_bytes(
                self.buf[byte..byte + 8].try_into().unwrap_or([0; 8]),
            );
            (w << bit, 64 - bit)
        } else if byte < self.buf.len() {
            // Tail: assemble the remaining (< 8) bytes top-aligned;
            // everything below them is zero by construction.
            let mut w = 0u64;
            for (i, &b) in self.buf[byte..].iter().enumerate() {
                w |= (b as u64) << (56 - 8 * i as u32);
            }
            (w << bit, self.bits_remaining() as u32)
        } else {
            (0, 0)
        }
    }

    /// Advance the read position by `n` bits, pairing with
    /// [`peek_word`](BitReader::peek_word): one `consume` per decoded
    /// symbol instead of one bounds check per bit. Clamped to the end
    /// of the buffer (reads from there return `None`, matching the
    /// bit-at-a-time readers).
    #[inline]
    pub fn consume(&mut self, n: u32) {
        self.pos = (self.pos + n as u64).min(self.buf.len() as u64 * 8);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn single_bits_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.put_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.get_bit(), Some(b));
        }
    }

    #[test]
    fn bits_roundtrip_random_widths() {
        let mut rng = Pcg::seed(42);
        let mut vals = Vec::new();
        let mut w = BitWriter::new();
        for _ in 0..500 {
            let n = rng.range(1, 65) as u32;
            let v = rng.next_u64() & (if n == 64 { u64::MAX } else { (1 << n) - 1 });
            w.put_bits(v, n);
            vals.push((v, n));
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for (v, n) in vals {
            assert_eq!(r.get_bits(n), Some(v), "width {n}");
        }
    }

    #[test]
    fn unary_roundtrip() {
        let mut w = BitWriter::new();
        for n in [0u64, 1, 2, 7, 8, 31, 100] {
            w.put_unary(n);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for n in [0u64, 1, 2, 7, 8, 31, 100] {
            assert_eq!(r.get_unary(), Some(n));
        }
    }

    #[test]
    fn eof_returns_none() {
        let mut w = BitWriter::new();
        w.put_bits(0b101, 3);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bits(3), Some(0b101));
        // Remaining padding bits (5) are readable zeros; beyond that: None.
        assert_eq!(r.get_bits(5), Some(0));
        assert_eq!(r.get_bit(), None);
        assert_eq!(r.get_bits(1), None);
        assert_eq!(r.get_unary(), None);
    }

    #[test]
    fn append_equals_replaying_the_bits() {
        // Write one reference stream; also write it as two halves split
        // at every possible bit position and append — all must agree.
        let mut rng = Pcg::seed(9);
        let bits: Vec<bool> = (0..75).map(|_| rng.next_u32() & 1 == 1).collect();
        let mut reference = BitWriter::new();
        for &b in &bits {
            reference.put_bit(b);
        }
        let ref_bytes = reference.as_bytes().to_vec();
        for split in 0..=bits.len() {
            let mut a = BitWriter::new();
            for &b in &bits[..split] {
                a.put_bit(b);
            }
            let mut b_writer = BitWriter::new();
            for &b in &bits[split..] {
                b_writer.put_bit(b);
            }
            a.append(&b_writer);
            assert_eq!(a.bit_len(), bits.len() as u64, "split {split}");
            assert_eq!(a.as_bytes(), &ref_bytes[..], "split {split}");
        }
        // Appending an empty writer is a no-op.
        let mut a = BitWriter::new();
        a.put_bits(0b1011, 4);
        let before = a.as_bytes().to_vec();
        a.append(&BitWriter::new());
        assert_eq!(a.as_bytes(), &before[..]);
        assert_eq!(a.bit_len(), 4);
    }

    #[test]
    fn seek_repositions_reads() {
        let mut w = BitWriter::new();
        w.put_bits(0b1010_1100_0111, 12);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bits(4), Some(0b1010));
        r.seek(8).unwrap();
        assert_eq!(r.get_bits(4), Some(0b0111));
        r.seek(0).unwrap();
        assert_eq!(r.get_bits(12), Some(0b1010_1100_0111));
        // Seeking to the exact end is valid; reads then return None.
        r.seek(16).unwrap();
        assert_eq!(r.get_bit(), None);
        assert!(r.seek(17).is_none());
    }

    #[test]
    fn bit_len_accounting() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.put_bits(0, 8);
        assert_eq!(w.bit_len(), 8);
        w.put_bit(true);
        assert_eq!(w.bit_len(), 9);
    }

    /// Differential: reading the stream through peek_word/consume in
    /// random-width slices must agree bit-for-bit with the
    /// bit-at-a-time oracle, at every alignment — including slices
    /// that straddle 64-bit window boundaries and the stream tail.
    #[test]
    fn peek_word_matches_bitwise_oracle_across_boundaries() {
        let mut rng = Pcg::seed(0x5eed);
        for case in 0..50 {
            let n_bytes = 1 + (rng.next_u32() % 40) as usize;
            let bytes: Vec<u8> = (0..n_bytes).map(|_| rng.next_u32() as u8).collect();
            let mut word = BitReader::new(&bytes);
            let mut oracle = BitReader::new(&bytes);
            loop {
                assert_eq!(word.bit_pos(), oracle.bit_pos(), "case {case}");
                let want = 1 + (rng.next_u32() % 64);
                let (w, avail) = word.peek_word();
                // Zero-fill contract: nothing below the valid bits.
                if avail < 64 {
                    assert_eq!(w & (u64::MAX >> avail), 0, "case {case}");
                }
                let take = want.min(avail);
                if take == 0 {
                    assert_eq!(oracle.get_bit(), None, "case {case}: oracle has more");
                    break;
                }
                let got = w >> (64 - take);
                let expect = oracle.get_bits(take).unwrap();
                assert_eq!(got, expect, "case {case} take {take}");
                word.consume(take);
            }
        }
    }

    /// The window's unary view matches get_unary wherever the whole
    /// run (ones + terminator) fits in the valid bits.
    #[test]
    fn peek_word_unary_matches_get_unary() {
        let mut rng = Pcg::seed(77);
        let mut w = BitWriter::new();
        let mut runs = Vec::new();
        for _ in 0..200 {
            let n = (rng.next_u32() % 20) as u64;
            w.put_unary(n);
            runs.push(n);
        }
        let bytes = w.into_bytes();
        let mut word = BitReader::new(&bytes);
        let mut oracle = BitReader::new(&bytes);
        for (i, &n) in runs.iter().enumerate() {
            let (win, avail) = word.peek_word();
            let ones = (!win).leading_zeros();
            assert_eq!(oracle.get_unary(), Some(n), "run {i}");
            if ones < avail {
                // Real terminator inside the window: counts agree and
                // one consume covers ones + terminator.
                assert_eq!(ones as u64, n, "run {i}");
                word.consume(ones + 1);
            } else {
                // Run straddles the window edge: fall back bitwise.
                assert_eq!(word.get_unary(), Some(n), "run {i} (fallback)");
            }
            assert_eq!(word.bit_pos(), oracle.bit_pos(), "run {i}");
        }
    }

    #[test]
    fn peek_word_tail_and_eof() {
        let bytes = [0xA5u8, 0xFF, 0x00];
        let mut r = BitReader::new(&bytes);
        // Tail path: fewer than 8 bytes left from the start.
        let (w, avail) = r.peek_word();
        assert_eq!(avail, 24);
        assert_eq!(w >> 40, 0xA5FF00);
        assert_eq!(w & (u64::MAX >> 24), 0);
        // Mid-byte alignment.
        r.consume(3);
        let (w, avail) = r.peek_word();
        assert_eq!(avail, 21);
        assert_eq!(w >> (64 - 21), (0xA5FF00u64 << 43 >> 43)); // low 21 bits
        // Consume clamps at the end; an empty window follows.
        r.consume(1000);
        assert_eq!(r.bit_pos(), 24);
        assert_eq!(r.peek_word(), (0, 0));
        assert_eq!(r.get_bit(), None);
    }
}
