//! Hand-rolled micro/macro benchmark harness (criterion is unavailable
//! offline).
//!
//! Each `benches/*.rs` target uses [`Bench`] to run warmups, timed
//! iterations, and emit a fixed-format row:
//!
//! ```text
//! bench golomb_decode/1M      mean=4.213ms  std=0.104ms  iters=30  thrpt=237.4 MB/s
//! ```
//!
//! plus an optional machine-readable JSONL file under `target/bench/`.

use crate::util::json::Json;
use crate::util::stats;
use std::io::Write as _;
use std::time::{Duration, Instant};

/// Configuration for one benchmark group.
pub struct Bench {
    group: String,
    warmup_iters: usize,
    measure_iters: usize,
    jsonl: Option<std::fs::File>,
}

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub mean: Duration,
    pub std: Duration,
    pub iters: usize,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        let iters = std::env::var("COMPEFT_BENCH_ITERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(20);
        let jsonl = std::fs::create_dir_all("target/bench").ok().and_then(|_| {
            std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(format!("target/bench/{group}.jsonl"))
                .ok()
        });
        Bench { group: group.to_string(), warmup_iters: 3, measure_iters: iters, jsonl }
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.measure_iters = n;
        self
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    /// Time `f` and report. Returns the measurement for programmatic use.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.report(name, &samples, None)
    }

    /// Time `f` which processes `bytes` per iteration; reports throughput.
    pub fn run_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        bytes: u64,
        mut f: F,
    ) -> Measurement {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.report(name, &samples, Some(bytes))
    }

    fn report(&mut self, name: &str, samples: &[f64], bytes: Option<u64>) -> Measurement {
        let mean = stats::mean(samples);
        let sd = stats::std(samples);
        let m = Measurement {
            name: format!("{}/{}", self.group, name),
            mean: Duration::from_secs_f64(mean),
            std: Duration::from_secs_f64(sd),
            iters: samples.len(),
        };
        let thrpt = bytes.map(|b| b as f64 / mean / 1e6);
        match thrpt {
            Some(t) => println!(
                "bench {:<44} mean={:>10}  std={:>10}  iters={:<3} thrpt={t:.1} MB/s",
                m.name,
                fmt_dur(m.mean),
                fmt_dur(m.std),
                m.iters
            ),
            None => println!(
                "bench {:<44} mean={:>10}  std={:>10}  iters={}",
                m.name,
                fmt_dur(m.mean),
                fmt_dur(m.std),
                m.iters
            ),
        }
        if let Some(file) = &mut self.jsonl {
            let mut j = Json::obj();
            j.set("name", Json::str(&m.name))
                .set("mean_s", Json::num(mean))
                .set("std_s", Json::num(sd))
                .set("iters", Json::num(samples.len() as f64));
            if let Some(t) = thrpt {
                j.set("mb_per_s", Json::num(t));
            }
            let _ = writeln!(file, "{}", j.to_string());
        }
        m
    }

    /// Print a free-form result row (for accuracy-style "benches" that
    /// reproduce paper tables rather than time code).
    pub fn row(&mut self, label: &str, fields: &[(&str, f64)]) {
        let mut line = format!("row   {}/{label:<38}", self.group);
        let mut j = Json::obj();
        j.set("name", Json::str(&format!("{}/{label}", self.group)));
        for (k, v) in fields {
            line.push_str(&format!(" {k}={v:.4}"));
            j.set(k, Json::num(*v));
        }
        println!("{line}");
        if let Some(file) = &mut self.jsonl {
            let _ = writeln!(file, "{}", j.to_string());
        }
    }
}

/// Scan a bench target's argv for `--json <path>` / `--json=<path>`.
/// Returns the artifact path, or None when the flag is absent.
pub fn json_flag(args: &[String]) -> Option<std::path::PathBuf> {
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--json" {
            return it.next().map(std::path::PathBuf::from);
        }
        if let Some(p) = a.strip_prefix("--json=") {
            return Some(std::path::PathBuf::from(p));
        }
    }
    None
}

/// Machine-readable result sink behind the benches' `--json <path>`
/// flag: collects rows and writes one JSON array of records with the
/// stable schema `{bench, row, value, unit, config}` — `bench` the
/// target name, `row` the `label/metric` pair, `config` the run
/// parameters shared by every record. CI uploads these `BENCH_*.json`
/// files as workflow artifacts.
pub struct JsonSink {
    path: std::path::PathBuf,
    bench: String,
    config: Json,
    records: Vec<Json>,
}

impl JsonSink {
    pub fn new(path: std::path::PathBuf, bench: &str, config: Json) -> JsonSink {
        JsonSink { path, bench: bench.to_string(), config, records: Vec::new() }
    }

    /// Append one record. `row` is conventionally `label/metric`
    /// (e.g. `steady/p999_us`); `unit` names `value`'s unit (`us`,
    /// `rps`, `frac`, `count`, ...).
    pub fn record(&mut self, row: &str, value: f64, unit: &str) {
        let mut j = Json::obj();
        j.set("bench", Json::str(&self.bench))
            .set("row", Json::str(row))
            .set("value", Json::num(value))
            .set("unit", Json::str(unit))
            .set("config", self.config.clone());
        self.records.push(j);
    }

    /// Record every field of a printed row under `label/<field>`.
    pub fn record_row(&mut self, label: &str, fields: &[(&str, f64, &str)]) {
        for (metric, value, unit) in fields {
            self.record(&format!("{label}/{metric}"), *value, unit);
        }
    }

    /// Write the artifact. Call once at the end of the bench run.
    pub fn write(&self) -> std::io::Result<()> {
        if let Some(dir) = self.path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        let doc = Json::Arr(self.records.clone());
        std::fs::write(&self.path, doc.to_string() + "\n")
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ---------------------------------------------------------------------------
// Peak-allocation tracking (for time/peak-memory bench rows)
// ---------------------------------------------------------------------------

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static ALLOC_CURRENT: AtomicUsize = AtomicUsize::new(0);
static ALLOC_PEAK: AtomicUsize = AtomicUsize::new(0);

/// A [`System`](std::alloc::System)-backed global allocator that tracks
/// live and peak heap bytes, so benches can report *measured* peak
/// memory (e.g. dense vs ternary-domain merging) instead of modeled
/// estimates. Install it in a bench binary:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: compeft::util::bench::PeakAlloc = compeft::util::bench::PeakAlloc;
/// ```
///
/// then bracket a measured region with [`PeakAlloc::reset_peak`] and
/// [`PeakAlloc::peak_bytes`]. Counters are process-wide and include
/// worker-thread allocations — which is the point: a parallel path's
/// scratch buffers count against it.
pub struct PeakAlloc;

unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live =
                ALLOC_CURRENT.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            ALLOC_PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        ALLOC_CURRENT.fetch_sub(layout.size(), Ordering::Relaxed);
    }
}

impl PeakAlloc {
    /// Currently live heap bytes.
    pub fn current_bytes() -> usize {
        ALLOC_CURRENT.load(Ordering::Relaxed)
    }

    /// High-water mark since the last [`PeakAlloc::reset_peak`].
    pub fn peak_bytes() -> usize {
        ALLOC_PEAK.load(Ordering::Relaxed)
    }

    /// Restart the high-water mark from the current live size. Returns
    /// the live size, so `peak_bytes() - reset_peak()` after a region
    /// is the region's net peak growth.
    pub fn reset_peak() -> usize {
        let live = ALLOC_CURRENT.load(Ordering::Relaxed);
        ALLOC_PEAK.store(live, Ordering::Relaxed);
        live
    }
}

/// Run `f` once and return (result, seconds, net peak heap bytes) — the
/// shared time/peak-memory bracket for dense-vs-ternary bench rows. The
/// result passes through [`black_box`] so the measured region cannot be
/// elided. Byte counts are meaningful only when [`PeakAlloc`] is
/// installed as the binary's `#[global_allocator]`; otherwise they
/// read 0.
pub fn measure_peak<T>(f: impl FnOnce() -> T) -> (T, f64, u64) {
    let baseline = PeakAlloc::reset_peak();
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    let peak = PeakAlloc::peak_bytes().saturating_sub(baseline) as u64;
    (black_box(out), secs, peak)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_produces_sane_measurement() {
        let mut b = Bench::new("selftest").iters(5).warmup(1);
        let m = b.run("sleep60us", || std::thread::sleep(Duration::from_micros(60)));
        assert!(m.mean >= Duration::from_micros(55), "mean={:?}", m.mean);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn json_flag_parses_both_spellings() {
        let args = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(
            json_flag(&args(&["--quick", "--json", "out.json"])),
            Some(std::path::PathBuf::from("out.json"))
        );
        assert_eq!(
            json_flag(&args(&["--json=a/b.json"])),
            Some(std::path::PathBuf::from("a/b.json"))
        );
        assert_eq!(json_flag(&args(&["--quick"])), None);
        assert_eq!(json_flag(&args(&["--json"])), None, "dangling flag");
    }

    #[test]
    fn json_sink_writes_stable_schema() {
        let dir = std::env::temp_dir().join(format!("compeft_sink_{}", std::process::id()));
        let path = dir.join("BENCH_test.json");
        let mut config = Json::obj();
        config.set("seed", Json::num(42.0));
        let mut sink = JsonSink::new(path.clone(), "service_load", config);
        sink.record("steady/p999_us", 1234.5, "us");
        sink.record_row("flash", &[("goodput_rps", 10.0, "rps"), ("shed_rate", 0.25, "frac")]);
        sink.write().unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let records = match &doc {
            Json::Arr(xs) => xs,
            other => panic!("artifact must be an array, got {other:?}"),
        };
        assert_eq!(records.len(), 3);
        for r in records {
            assert_eq!(r.get("bench").and_then(|j| j.as_str()), Some("service_load"));
            for key in ["row", "value", "unit", "config"] {
                assert!(r.get(key).is_some(), "record missing {key}");
            }
            assert_eq!(
                r.get("config").and_then(|c| c.get("seed")).and_then(|j| j.as_f64()),
                Some(42.0)
            );
        }
        assert_eq!(records[0].get("row").and_then(|j| j.as_str()), Some("steady/p999_us"));
        assert_eq!(records[2].get("unit").and_then(|j| j.as_str()), Some("frac"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fmt_dur_scales() {
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.000s");
        assert_eq!(fmt_dur(Duration::from_millis(5)), "5.000ms");
        assert_eq!(fmt_dur(Duration::from_micros(7)), "7.000µs");
    }
}
