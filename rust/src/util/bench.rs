//! Hand-rolled micro/macro benchmark harness (criterion is unavailable
//! offline).
//!
//! Each `benches/*.rs` target uses [`Bench`] to run warmups, timed
//! iterations, and emit a fixed-format row:
//!
//! ```text
//! bench golomb_decode/1M      mean=4.213ms  std=0.104ms  iters=30  thrpt=237.4 MB/s
//! ```
//!
//! plus an optional machine-readable JSONL file under `target/bench/`.

use crate::util::json::Json;
use crate::util::stats;
use std::io::Write as _;
use std::time::{Duration, Instant};

/// Configuration for one benchmark group.
pub struct Bench {
    group: String,
    warmup_iters: usize,
    measure_iters: usize,
    jsonl: Option<std::fs::File>,
}

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub mean: Duration,
    pub std: Duration,
    pub iters: usize,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        let iters = std::env::var("COMPEFT_BENCH_ITERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(20);
        let jsonl = std::fs::create_dir_all("target/bench").ok().and_then(|_| {
            std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(format!("target/bench/{group}.jsonl"))
                .ok()
        });
        Bench { group: group.to_string(), warmup_iters: 3, measure_iters: iters, jsonl }
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.measure_iters = n;
        self
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    /// Time `f` and report. Returns the measurement for programmatic use.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.report(name, &samples, None)
    }

    /// Time `f` which processes `bytes` per iteration; reports throughput.
    pub fn run_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        bytes: u64,
        mut f: F,
    ) -> Measurement {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.report(name, &samples, Some(bytes))
    }

    fn report(&mut self, name: &str, samples: &[f64], bytes: Option<u64>) -> Measurement {
        let mean = stats::mean(samples);
        let sd = stats::std(samples);
        let m = Measurement {
            name: format!("{}/{}", self.group, name),
            mean: Duration::from_secs_f64(mean),
            std: Duration::from_secs_f64(sd),
            iters: samples.len(),
        };
        let thrpt = bytes.map(|b| b as f64 / mean / 1e6);
        match thrpt {
            Some(t) => println!(
                "bench {:<44} mean={:>10}  std={:>10}  iters={:<3} thrpt={t:.1} MB/s",
                m.name,
                fmt_dur(m.mean),
                fmt_dur(m.std),
                m.iters
            ),
            None => println!(
                "bench {:<44} mean={:>10}  std={:>10}  iters={}",
                m.name,
                fmt_dur(m.mean),
                fmt_dur(m.std),
                m.iters
            ),
        }
        if let Some(file) = &mut self.jsonl {
            let mut j = Json::obj();
            j.set("name", Json::str(&m.name))
                .set("mean_s", Json::num(mean))
                .set("std_s", Json::num(sd))
                .set("iters", Json::num(samples.len() as f64));
            if let Some(t) = thrpt {
                j.set("mb_per_s", Json::num(t));
            }
            let _ = writeln!(file, "{}", j.to_string());
        }
        m
    }

    /// Print a free-form result row (for accuracy-style "benches" that
    /// reproduce paper tables rather than time code).
    pub fn row(&mut self, label: &str, fields: &[(&str, f64)]) {
        let mut line = format!("row   {}/{label:<38}", self.group);
        let mut j = Json::obj();
        j.set("name", Json::str(&format!("{}/{label}", self.group)));
        for (k, v) in fields {
            line.push_str(&format!(" {k}={v:.4}"));
            j.set(k, Json::num(*v));
        }
        println!("{line}");
        if let Some(file) = &mut self.jsonl {
            let _ = writeln!(file, "{}", j.to_string());
        }
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ---------------------------------------------------------------------------
// Peak-allocation tracking (for time/peak-memory bench rows)
// ---------------------------------------------------------------------------

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static ALLOC_CURRENT: AtomicUsize = AtomicUsize::new(0);
static ALLOC_PEAK: AtomicUsize = AtomicUsize::new(0);

/// A [`System`](std::alloc::System)-backed global allocator that tracks
/// live and peak heap bytes, so benches can report *measured* peak
/// memory (e.g. dense vs ternary-domain merging) instead of modeled
/// estimates. Install it in a bench binary:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: compeft::util::bench::PeakAlloc = compeft::util::bench::PeakAlloc;
/// ```
///
/// then bracket a measured region with [`PeakAlloc::reset_peak`] and
/// [`PeakAlloc::peak_bytes`]. Counters are process-wide and include
/// worker-thread allocations — which is the point: a parallel path's
/// scratch buffers count against it.
pub struct PeakAlloc;

unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live =
                ALLOC_CURRENT.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            ALLOC_PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        ALLOC_CURRENT.fetch_sub(layout.size(), Ordering::Relaxed);
    }
}

impl PeakAlloc {
    /// Currently live heap bytes.
    pub fn current_bytes() -> usize {
        ALLOC_CURRENT.load(Ordering::Relaxed)
    }

    /// High-water mark since the last [`PeakAlloc::reset_peak`].
    pub fn peak_bytes() -> usize {
        ALLOC_PEAK.load(Ordering::Relaxed)
    }

    /// Restart the high-water mark from the current live size. Returns
    /// the live size, so `peak_bytes() - reset_peak()` after a region
    /// is the region's net peak growth.
    pub fn reset_peak() -> usize {
        let live = ALLOC_CURRENT.load(Ordering::Relaxed);
        ALLOC_PEAK.store(live, Ordering::Relaxed);
        live
    }
}

/// Run `f` once and return (result, seconds, net peak heap bytes) — the
/// shared time/peak-memory bracket for dense-vs-ternary bench rows. The
/// result passes through [`black_box`] so the measured region cannot be
/// elided. Byte counts are meaningful only when [`PeakAlloc`] is
/// installed as the binary's `#[global_allocator]`; otherwise they
/// read 0.
pub fn measure_peak<T>(f: impl FnOnce() -> T) -> (T, f64, u64) {
    let baseline = PeakAlloc::reset_peak();
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    let peak = PeakAlloc::peak_bytes().saturating_sub(baseline) as u64;
    (black_box(out), secs, peak)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_produces_sane_measurement() {
        let mut b = Bench::new("selftest").iters(5).warmup(1);
        let m = b.run("sleep60us", || std::thread::sleep(Duration::from_micros(60)));
        assert!(m.mean >= Duration::from_micros(55), "mean={:?}", m.mean);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn fmt_dur_scales() {
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.000s");
        assert_eq!(fmt_dur(Duration::from_millis(5)), "5.000ms");
        assert_eq!(fmt_dur(Duration::from_micros(7)), "7.000µs");
    }
}
