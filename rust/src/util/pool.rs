//! A small fixed-size worker thread pool.
//!
//! tokio is unavailable offline; the coordinator's concurrency needs are
//! bounded (decode pipeline, batch execution, background prefetch), so a
//! classic channel-fed pool with graceful shutdown is sufficient and
//! keeps the request path allocation-light.

use crate::util::sync::{rank, OrderedMutex};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Dropping the pool joins all workers.
///
/// The submission handle is wrapped in a `Mutex` so the pool is `Sync`
/// on every toolchain (std's `mpsc::Sender` only became `Sync` in Rust
/// 1.72): the serving engine shares one decode pool between the engine
/// thread and the background prefetch threads. The lock is held only
/// for the channel send, never while a job runs.
pub struct ThreadPool {
    tx: Option<OrderedMutex<mpsc::Sender<Job>>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n >= 1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(OrderedMutex::new(rank::POOL_RECEIVER, "pool.receiver", rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("compeft-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(OrderedMutex::new(rank::POOL_SENDER, "pool.sender", tx)),
            workers,
        }
    }

    /// Submit a job for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit(Box::new(f));
    }

    /// Submit an already-boxed job (no second allocation).
    fn submit(&self, job: Job) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .lock()
            .unwrap()
            .send(job)
            .expect("workers alive");
    }

    /// Run `f` over each item of `items` on the pool and collect results
    /// in input order. Blocks until all are done. (The `'static` special
    /// case of [`ThreadPool::scoped_map`].)
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.scoped_map(items, f)
    }

    /// Like [`ThreadPool::map`], but the items and the closure may
    /// borrow from the caller's stack. Blocks until every submitted job
    /// has completed, which is what makes the lifetime extension sound:
    /// no job can outlive this call.
    ///
    /// A panicking closure is caught on the worker (keeping the pool
    /// healthy and the job accounting intact) and re-raised on the
    /// caller after *all* jobs have finished — so even on panic, no job
    /// that borrows the caller's stack survives this call.
    ///
    /// Contract: `scoped_map` must not be called from inside a job
    /// running on the same pool (the outer job would block a worker
    /// while waiting for inner jobs that need workers — deadlock at
    /// full occupancy). The compression engine parallelises exactly one
    /// level (chunks *or* tensors *or* parts, never nested).
    pub fn scoped_map<'env, T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'env,
        R: Send + 'env,
        F: Fn(T) -> R + Sync + 'env,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        type JobResult<R> = std::thread::Result<R>; // Ok(R) | Err(panic payload)
        let (rtx, rrx) = mpsc::channel::<(usize, JobResult<R>)>();
        let f_ref: &(dyn Fn(T) -> R + Sync) = &f;
        for (i, item) in items.into_iter().enumerate() {
            let rtx = rtx.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let result = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| f_ref(item)),
                );
                let _ = rtx.send((i, result));
            });
            // SAFETY: the job is a fat Box<dyn FnOnce> whose non-'static
            // captures borrow from `f` and 'env, both of which outlive
            // this call. Every job sends exactly once — catch_unwind
            // converts a panicking closure into a sent Err, so workers
            // never unwind and queued jobs always run — and the loop
            // below receives all `n` results (then re-raises any panic)
            // before this function returns. Hence no job, running or
            // queued, can outlive the borrowed frame. Box<dyn Trait + '_>
            // and Box<dyn Trait + 'static> have identical layout.
            let job: Box<dyn FnOnce() + Send + 'static> =
                unsafe { std::mem::transmute(job) };
            self.submit(job);
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panic_payload = None;
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("scoped_map worker died");
            match r {
                Ok(v) => out[i] = Some(v),
                Err(payload) => panic_payload = Some(payload),
            }
        }
        if let Some(payload) = panic_payload {
            std::panic::resume_unwind(payload);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

/// Split `[0, len)` into `[start, end)` ranges of at most `chunk`
/// elements, in order. The engine's parallel passes map over these.
pub fn chunk_ranges(len: usize, chunk: usize) -> Vec<(usize, usize)> {
    let chunk = chunk.max(1);
    let mut out = Vec::with_capacity(len.div_ceil(chunk));
    let mut start = 0;
    while start < len {
        let end = (start + chunk).min(len);
        out.push((start, end));
        start = end;
    }
    out
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit on recv Err
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i32>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<i32>>());
    }

    #[test]
    fn single_worker_works() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn scoped_map_borrows_stack_data() {
        let data: Vec<u64> = (0..10_000).collect();
        for workers in crate::util::prop::pool_sizes() {
            let pool = ThreadPool::new(workers);
            let ranges = chunk_ranges(data.len(), 997);
            let sums = pool.scoped_map(ranges.clone(), |(s, e)| {
                data[s..e].iter().sum::<u64>()
            });
            assert_eq!(sums.len(), ranges.len());
            let total: u64 = sums.iter().sum();
            assert_eq!(total, data.iter().sum::<u64>(), "workers={workers}");
        }
    }

    #[test]
    fn scoped_map_preserves_order_and_handles_empty() {
        let pool = ThreadPool::new(4);
        let squares = pool.scoped_map((0..100u32).collect(), |x| x * x);
        assert_eq!(squares, (0..100u32).map(|x| x * x).collect::<Vec<_>>());
        let empty: Vec<u32> = pool.scoped_map(Vec::<u32>::new(), |x| x);
        assert!(empty.is_empty());
    }

    #[test]
    fn scoped_map_propagates_panics_after_draining() {
        let pool = ThreadPool::new(2);
        let data = vec![1u32, 2, 3, 4, 5];
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scoped_map(data.clone(), |x| {
                if x == 3 {
                    panic!("boom on {x}");
                }
                x * 2
            })
        }));
        assert!(caught.is_err(), "panic must propagate to the caller");
        // The pool survives a panicking job and keeps serving.
        let ok = pool.scoped_map(data, |x| x + 1);
        assert_eq!(ok, vec![2, 3, 4, 5, 6]);
    }

    /// The serving pipeline shares one decode pool between the engine
    /// thread and the prefetch threads: concurrent `scoped_map` calls
    /// from different caller threads must interleave safely, each
    /// collecting exactly its own results.
    #[test]
    fn concurrent_scoped_map_callers_share_one_pool() {
        let pool = Arc::new(ThreadPool::new(3));
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let out = pool.map((0..200u64).collect::<Vec<_>>(), move |x| x * 7 + t);
                    assert_eq!(
                        out,
                        (0..200u64).map(|x| x * 7 + t).collect::<Vec<_>>()
                    );
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        assert_eq!(chunk_ranges(0, 10), Vec::<(usize, usize)>::new());
        assert_eq!(chunk_ranges(5, 10), vec![(0, 5)]);
        assert_eq!(chunk_ranges(10, 5), vec![(0, 5), (5, 10)]);
        assert_eq!(chunk_ranges(11, 5), vec![(0, 5), (5, 10), (10, 11)]);
        // chunk = 0 is clamped rather than looping forever
        assert_eq!(chunk_ranges(3, 0), vec![(0, 1), (1, 2), (2, 3)]);
    }
}
