//! A small fixed-size worker thread pool.
//!
//! tokio is unavailable offline; the coordinator's concurrency needs are
//! bounded (decode pipeline, batch execution, background prefetch), so a
//! classic channel-fed pool with graceful shutdown is sufficient and
//! keeps the request path allocation-light.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n >= 1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("compeft-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Submit a job for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Run `f` over each item of `items` on the pool and collect results
    /// in input order. Blocks until all are done.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit on recv Err
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i32>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<i32>>());
    }

    #[test]
    fn single_worker_works() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
