//! Rank-ordered mutexes: the runtime twin of the static `lock-order`
//! lint (`analysis::lockorder`).
//!
//! Every coordinator mutex belongs to a named lock class with a fixed
//! rank (the [`rank`] constants; the class table with docs lives in
//! `analysis::lockorder::LOCK_CLASSES` and the blessed order is
//! documented in `coordinator/mod.rs`). A thread must acquire locks in
//! strictly increasing rank. In debug builds (`debug_assertions`) a
//! thread-local stack of held ranks enforces this at runtime and
//! panics on the first out-of-order or re-entrant acquisition — so a
//! deadlock that would need a lucky interleaving to manifest fails
//! deterministically on any single-threaded test that merely *walks*
//! the wrong path. Release builds compile the tracking away;
//! [`OrderedMutex`] is then a plain `Mutex` plus two words.
//!
//! The API mirrors `std::sync::Mutex` closely enough that existing
//! `.lock().unwrap()` call sites compile unchanged. Condvar waits go
//! through the guard ([`OrderedGuard::wait`] /
//! [`OrderedGuard::wait_timeout`]) because `std::sync::Condvar` wants
//! the raw `MutexGuard`: the wait keeps the rank on the stack — the
//! thread still logically holds its place in the order while blocked —
//! and re-wraps the reacquired guard without re-pushing.

use std::sync::{Condvar, LockResult, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Declared lock ranks. Acquire in strictly increasing rank only.
/// Mirrored by `analysis::lockorder::LOCK_CLASSES`; keep both in sync
/// (a lockorder test pins them together).
pub mod rank {
    /// `Batcher.queues` — request queues + admission state.
    pub const BATCHER_QUEUES: u32 = 10;
    /// `PfShared.plan` — prefetcher's desired-expert plan.
    pub const PREFETCH_PLAN: u32 = 20;
    /// `StagingArea.inner` — staged decoded experts.
    pub const STAGING: u32 = 30;
    /// The shared CPU `LruTier` (pipeline/server `cpu`).
    pub const CPU_TIER: u32 = 40;
    /// `ExpertStore.epoch` — current placement view + node links.
    pub const STORE_EPOCH: u32 = 44;
    /// `ExpertStore.stats` — per-expert fetch popularity counters.
    pub const STORE_STATS: u32 = 46;
    /// `SimLink.state` — transport byte/transfer accounting.
    pub const LINK_STATE: u32 = 50;
    /// `ThreadPool.tx` — job submission channel.
    pub const POOL_SENDER: u32 = 60;
    /// `ThreadPool` worker receiver.
    pub const POOL_RECEIVER: u32 = 61;
    /// `BundleCache.exes` — compiled executable cache.
    pub const EXEC_CACHE: u32 = 70;
    /// `Metrics.inner` — counters; leaf rank, safe to bump anywhere.
    pub const METRICS: u32 = 80;
}

#[cfg(debug_assertions)]
mod tracker {
    use std::cell::RefCell;

    thread_local! {
        static HELD: RefCell<Vec<(u32, &'static str)>> =
            const { RefCell::new(Vec::new()) };
    }

    /// Check the new rank against every held rank, then push it.
    /// Guards may drop in any order, so the check is against the max
    /// held rank, not just the top of stack.
    pub fn acquire(rank: u32, name: &'static str) {
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            if let Some(&(r, n)) = h.iter().find(|&&(r, _)| r >= rank) {
                panic!(
                    "lock-order violation: acquiring `{name}` (rank {rank}) \
                     while holding `{n}` (rank {r}); held = {:?}",
                    h.as_slice()
                );
            }
            h.push((rank, name));
        });
    }

    /// Remove the most recent entry with this rank (guards can drop
    /// non-LIFO, so we match by rank rather than popping blindly).
    pub fn release(rank: u32) {
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            if let Some(i) = h.iter().rposition(|&(r, _)| r == rank) {
                h.remove(i);
            }
        });
    }
}

#[cfg(not(debug_assertions))]
mod tracker {
    #[inline(always)]
    pub fn acquire(_rank: u32, _name: &'static str) {}
    #[inline(always)]
    pub fn release(_rank: u32) {}
}

/// A `Mutex` tagged with a lock-class rank, checked in debug builds.
pub struct OrderedMutex<T: ?Sized> {
    rank: u32,
    name: &'static str,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    pub fn new(rank: u32, name: &'static str, value: T) -> OrderedMutex<T> {
        OrderedMutex { rank, name, inner: Mutex::new(value) }
    }

    /// Acquire, panicking (debug builds) if a held lock has rank >=
    /// this one. Returns `LockResult` like `Mutex::lock`, so existing
    /// `.lock().unwrap()` call sites are unchanged.
    pub fn lock(&self) -> LockResult<OrderedGuard<'_, T>> {
        tracker::acquire(self.rank, self.name);
        match self.inner.lock() {
            Ok(g) => Ok(self.wrap(g)),
            Err(p) => Err(PoisonError::new(self.wrap(p.into_inner()))),
        }
    }

    fn wrap<'a>(&self, g: MutexGuard<'a, T>) -> OrderedGuard<'a, T> {
        OrderedGuard { inner: Some(g), rank: self.rank, name: self.name }
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OrderedMutex")
            .field("name", &self.name)
            .field("rank", &self.rank)
            .finish_non_exhaustive()
    }
}

/// Guard for [`OrderedMutex`]; releases the rank entry on drop.
///
/// `inner` is `Some` for the guard's whole life; it is only taken by
/// the wait methods, which consume `self` (the rank entry survives the
/// wait — see module docs).
pub struct OrderedGuard<'a, T: ?Sized> {
    inner: Option<MutexGuard<'a, T>>,
    rank: u32,
    name: &'static str,
}

impl<'a, T: ?Sized> OrderedGuard<'a, T> {
    /// Block on `cv`, releasing the mutex while waiting and re-wrapping
    /// the reacquired guard. The rank stays on this thread's stack for
    /// the duration (the thread is blocked; it cannot acquire anything
    /// else anyway).
    pub fn wait(mut self, cv: &Condvar) -> LockResult<OrderedGuard<'a, T>> {
        let (rank, name) = (self.rank, self.name);
        let g = self.inner.take().expect("guard holds its MutexGuard until dropped");
        drop(self); // inner is None: drop releases nothing
        match cv.wait(g) {
            Ok(g) => Ok(OrderedGuard { inner: Some(g), rank, name }),
            Err(p) => Err(PoisonError::new(OrderedGuard {
                inner: Some(p.into_inner()),
                rank,
                name,
            })),
        }
    }

    /// [`OrderedGuard::wait`] with a timeout.
    pub fn wait_timeout(
        mut self,
        cv: &Condvar,
        dur: Duration,
    ) -> LockResult<(OrderedGuard<'a, T>, WaitTimeoutResult)> {
        let (rank, name) = (self.rank, self.name);
        let g = self.inner.take().expect("guard holds its MutexGuard until dropped");
        drop(self);
        match cv.wait_timeout(g, dur) {
            Ok((g, t)) => Ok((OrderedGuard { inner: Some(g), rank, name }, t)),
            Err(p) => {
                let (g, t) = p.into_inner();
                Err(PoisonError::new((
                    OrderedGuard { inner: Some(g), rank, name },
                    t,
                )))
            }
        }
    }
}

impl<T: ?Sized> std::ops::Deref for OrderedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard holds its MutexGuard until dropped")
    }
}

impl<T: ?Sized> std::ops::DerefMut for OrderedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard holds its MutexGuard until dropped")
    }
}

impl<T: ?Sized> Drop for OrderedGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.is_some() {
            tracker::release(self.rank);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn blessed_order_is_silent() {
        // plan (20) -> staging (30) -> metrics (80): the coordinator's
        // real nesting. Must not panic in any build.
        let plan = OrderedMutex::new(rank::PREFETCH_PLAN, "pipeline.plan", 0u32);
        let staging = OrderedMutex::new(rank::STAGING, "pipeline.staging", 0u32);
        let metrics = OrderedMutex::new(rank::METRICS, "metrics.inner", 0u32);
        let p = plan.lock().unwrap();
        let s = staging.lock().unwrap();
        let m = metrics.lock().unwrap();
        drop((p, s, m));
        // Reacquire after release: the stack must be clean.
        let _m = metrics.lock().unwrap();
        let _p = plan.lock().unwrap();
    }

    #[test]
    fn non_lifo_drop_keeps_tracking_consistent() {
        let a = OrderedMutex::new(rank::BATCHER_QUEUES, "batcher.queues", ());
        let b = OrderedMutex::new(rank::STAGING, "pipeline.staging", ());
        let c = OrderedMutex::new(rank::CPU_TIER, "cache.cpu_tier", ());
        let ga = a.lock().unwrap();
        let gb = b.lock().unwrap();
        drop(ga); // out of acquisition order
        let gc = c.lock().unwrap();
        drop(gb);
        drop(gc);
        // Everything released: low rank acquires cleanly again.
        let _ga = a.lock().unwrap();
    }

    /// Runtime twin of the lint's seeded out-of-order fixture
    /// (`analysis::lockorder::tests::seeded_out_of_order_fires`):
    /// staging then plan must panic under the debug tracker.
    #[test]
    #[cfg(debug_assertions)]
    fn out_of_order_acquisition_panics_in_debug() {
        let err = std::thread::spawn(|| {
            let plan =
                OrderedMutex::new(rank::PREFETCH_PLAN, "pipeline.plan", 0u32);
            let staging =
                OrderedMutex::new(rank::STAGING, "pipeline.staging", 0u32);
            let _s = staging.lock().unwrap();
            let _p = plan.lock().unwrap(); // rank 20 under rank 30: boom
        })
        .join()
        .expect_err("out-of-order acquisition must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("lock-order violation"), "{msg}");
        assert!(msg.contains("pipeline.plan"), "{msg}");
        assert!(msg.contains("pipeline.staging"), "{msg}");
    }

    #[test]
    #[cfg(debug_assertions)]
    fn re_entrant_acquisition_panics_in_debug() {
        let err = std::thread::spawn(|| {
            let m = Arc::new(OrderedMutex::new(rank::METRICS, "metrics.inner", ()));
            let _a = m.lock().unwrap();
            let _b = m.lock().unwrap(); // same rank: self-deadlock
        })
        .join()
        .expect_err("re-entrant acquisition must panic");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("lock-order violation"), "{msg}");
    }

    #[test]
    fn condvar_wait_roundtrip_preserves_rank() {
        let staging = Arc::new(OrderedMutex::new(rank::STAGING, "pipeline.staging", 0u32));
        let cv = Arc::new(Condvar::new());
        let (s2, cv2) = (Arc::clone(&staging), Arc::clone(&cv));
        let t = std::thread::spawn(move || {
            let mut g = s2.lock().unwrap();
            while *g == 0 {
                g = g.wait(&cv2).unwrap();
            }
            *g
        });
        // Give the waiter a chance to park, then publish.
        std::thread::sleep(Duration::from_millis(10));
        *staging.lock().unwrap() = 7;
        cv.notify_all();
        assert_eq!(t.join().unwrap(), 7);
        // The waiter's wait/re-wrap cycle must leave this thread's
        // tracker clean for a fresh blessed-order pass.
        let _g = staging.lock().unwrap();
    }

    #[test]
    fn condvar_wait_timeout_times_out_and_releases() {
        let m = OrderedMutex::new(rank::BATCHER_QUEUES, "batcher.queues", ());
        let cv = Condvar::new();
        let g = m.lock().unwrap();
        let (g, res) = g.wait_timeout(&cv, Duration::from_millis(5)).unwrap();
        assert!(res.timed_out());
        drop(g);
        let _again = m.lock().unwrap();
    }

    #[test]
    fn poisoned_lock_still_returns_guard() {
        let m = Arc::new(OrderedMutex::new(rank::METRICS, "metrics.inner", 3u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        // Poisoned: Err carries a usable guard, mirroring std.
        let v = match m.lock() {
            Ok(g) => *g,
            Err(p) => *p.into_inner(),
        };
        assert_eq!(v, 3);
        // Tracker stayed balanced through the poison path.
        let _again = m.lock();
    }
}
