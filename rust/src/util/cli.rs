//! Declarative command-line flag parsing (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, defaults,
//! required flags, and generated `--help` text. Each binary/subcommand
//! builds an [`ArgSpec`] and calls [`ArgSpec::parse`].

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct FlagDef {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    required: bool,
    is_bool: bool,
}

/// Specification of the flags a command accepts.
#[derive(Clone, Debug, Default)]
pub struct ArgSpec {
    command: &'static str,
    about: &'static str,
    flags: Vec<FlagDef>,
}

/// Parsed argument values.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl ArgSpec {
    pub fn new(command: &'static str, about: &'static str) -> Self {
        ArgSpec { command, about, flags: Vec::new() }
    }

    /// Optional flag with a default value.
    pub fn flag(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagDef {
            name,
            help,
            default: Some(default.to_string()),
            required: false,
            is_bool: false,
        });
        self
    }

    /// Required flag (no default).
    pub fn required(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagDef { name, help, default: None, required: true, is_bool: false });
        self
    }

    /// Boolean flag (presence = true).
    pub fn boolean(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagDef {
            name,
            help,
            default: Some("false".to_string()),
            required: false,
            is_bool: true,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nFlags:\n", self.command, self.about);
        for f in &self.flags {
            let kind = if f.is_bool {
                String::new()
            } else if let Some(d) = &f.default {
                format!(" <value> (default: {d})")
            } else {
                " <value> (required)".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", f.name, kind, f.help));
        }
        s
    }

    /// Parse raw argv (not including the program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut values = BTreeMap::new();
        for f in &self.flags {
            if let Some(d) = &f.default {
                values.insert(f.name.to_string(), d.clone());
            }
        }
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                bail!("{}", self.help_text());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let def = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| {
                        anyhow::anyhow!("unknown flag --{name}\n\n{}", self.help_text())
                    })?;
                let value = if def.is_bool {
                    match inline {
                        Some(v) => v,
                        None => "true".to_string(),
                    }
                } else {
                    match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            if i >= argv.len() {
                                bail!("flag --{name} expects a value");
                            }
                            argv[i].clone()
                        }
                    }
                };
                values.insert(name, value);
            } else {
                positional.push(tok.clone());
            }
            i += 1;
        }
        for f in &self.flags {
            if f.required && !values.contains_key(f.name) {
                bail!("missing required flag --{}\n\n{}", f.name, self.help_text());
            }
        }
        Ok(Args { values, positional })
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag {name} not declared in spec"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self.get(name);
        v.parse().map_err(|_| anyhow::anyhow!("--{name}: expected integer, got {v:?}"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        let v = self.get(name);
        v.parse().map_err(|_| anyhow::anyhow!("--{name}: expected integer, got {v:?}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self.get(name);
        v.parse().map_err(|_| anyhow::anyhow!("--{name}: expected number, got {v:?}"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), "true" | "1" | "yes")
    }

    /// Comma-separated list helper.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        let v = self.get(name);
        if v.is_empty() {
            Vec::new()
        } else {
            v.split(',').map(|s| s.trim().to_string()).collect()
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn spec() -> ArgSpec {
        ArgSpec::new("test", "test command")
            .flag("k", "20", "density percent")
            .flag("alpha", "1.0", "scaling")
            .required("input", "input path")
            .boolean("verbose", "chatty")
    }

    #[test]
    fn defaults_and_required() {
        let a = spec().parse(&argv(&["--input", "x.npz"])).unwrap();
        assert_eq!(a.get("k"), "20");
        assert_eq!(a.get_f64("alpha").unwrap(), 1.0);
        assert_eq!(a.get("input"), "x.npz");
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn equals_syntax_and_bool() {
        let a = spec()
            .parse(&argv(&["--input=y.npz", "--k=5", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_usize("k").unwrap(), 5);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&argv(&["--k", "5"])).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(spec().parse(&argv(&["--input", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = spec().parse(&argv(&["--input", "x", "pos1", "pos2"])).unwrap();
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn list_parsing() {
        let s = ArgSpec::new("t", "t").flag("tasks", "a,b,c", "tasks");
        let a = s.parse(&[]).unwrap();
        assert_eq!(a.get_list("tasks"), vec!["a", "b", "c"]);
    }
}
