//! A small, dependency-free Rust lexer for `compeft-lint`.
//!
//! Produces a token stream with line numbers, plus the side channels
//! the rule engine needs: `// compeft-lint: allow(...)` directives and
//! per-token test-region membership (`#[cfg(test)]` items, `#[test]`
//! functions, `mod tests`). It handles the lexical constructs that trip
//! naive scanners: raw strings (`r#"..."#`, any hash depth), byte and
//! byte-raw strings, nested block comments, and the char-literal vs
//! lifetime ambiguity (`'x'` vs `'x`).
//!
//! This is an analysis lexer, not a compiler front end: numeric
//! literals and multi-char operators are tokenized loosely (rules only
//! look at identifiers, punctuation adjacency, and strings), but
//! string/comment/char boundaries — the things that could hide or
//! fabricate a `.lock()` — are exact.

/// Token kind. Strings and chars keep no contents: rules must never
/// match inside literals, so dropping the text enforces that.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword.
    Ident(String),
    /// `'a`, `'static` — lifetimes (distinct from char literals).
    Lifetime,
    /// `'x'`, `'\n'`, `b'x'` — char/byte literals.
    CharLit,
    /// Any string literal: `"…"`, `r#"…"#`, `b"…"`, `br#"…"#`.
    StrLit,
    /// Numeric literal (loosely tokenized).
    Num,
    /// Single punctuation character: `. ( ) [ ] { } ; : , # ! & …`.
    Punct(char),
}

/// One token with its source position and test-region membership.
#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    /// 1-based source line.
    pub line: u32,
    /// True inside `#[cfg(test)]` / `#[test]` items or `mod tests`.
    pub in_test: bool,
}

impl Token {
    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.tok == Tok::Punct(c)
    }
}

/// A `// compeft-lint: allow(rule-a, rule-b) -- reason` directive.
#[derive(Clone, Debug)]
pub struct Allow {
    /// Line the comment sits on.
    pub line: u32,
    /// Rule ids named inside `allow(...)`.
    pub rules: Vec<String>,
    /// True when a non-empty reason follows `--`.
    pub has_reason: bool,
    /// True when the comment is alone on its line (then it also covers
    /// the next line).
    pub own_line: bool,
    /// True when the directive sits inside a test region.
    pub in_test: bool,
}

impl Allow {
    /// Lines this directive suppresses: its own line, plus the next
    /// line when the comment stands alone.
    pub fn covers(&self, line: u32) -> bool {
        line == self.line || (self.own_line && line == self.line + 1)
    }
}

/// Lexed file: tokens plus lint directives.
#[derive(Debug, Default)]
pub struct LexFile {
    pub tokens: Vec<Token>,
    pub allows: Vec<Allow>,
}

const DIRECTIVE: &str = "compeft-lint:";

/// Lex `src`. Never fails: unterminated constructs are closed at EOF
/// (the analyzer must degrade, not die, on odd input).
pub fn lex(src: &str) -> LexFile {
    let b = src.as_bytes();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut tokens: Vec<Token> = Vec::new();
    let mut allows: Vec<Allow> = Vec::new();
    // Becomes true when anything other than whitespace was seen since
    // the start of the current line (drives `Allow::own_line`).
    let mut line_has_code = false;

    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                line_has_code = false;
                i += 1;
            }
            c if (c as char).is_ascii_whitespace() => i += 1,
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                // Line comment; may carry a lint directive.
                let start = i;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                let text = &src[start..i];
                if let Some(a) = parse_directive(text, line, !line_has_code) {
                    allows.push(a);
                }
                line_has_code = true;
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                // Nested block comment.
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        if b[i] == b'\n' {
                            line += 1;
                        }
                        i += 1;
                    }
                }
                line_has_code = true;
            }
            b'"' => {
                let tl = line;
                i = skip_cooked_string(b, i + 1, &mut line);
                push(&mut tokens, Tok::StrLit, tl);
                line_has_code = true;
            }
            b'\'' => {
                // Char literal vs lifetime.
                let tl = line;
                if is_char_literal(b, i) {
                    i = skip_char_literal(b, i + 1);
                    push(&mut tokens, Tok::CharLit, tl);
                } else {
                    i += 1;
                    while i < b.len() && is_ident_byte(b[i]) {
                        i += 1;
                    }
                    push(&mut tokens, Tok::Lifetime, tl);
                }
                line_has_code = true;
            }
            c if c.is_ascii_digit() => {
                let tl = line;
                i = skip_number(b, i);
                push(&mut tokens, Tok::Num, tl);
                line_has_code = true;
            }
            c if is_ident_start(c) => {
                let tl = line;
                // Raw/byte string prefixes: r"…", r#"…"#, b"…", br#"…"#.
                if let Some(next) = raw_or_byte_string_end(b, i, &mut line) {
                    i = next;
                    push(&mut tokens, Tok::StrLit, tl);
                } else {
                    let start = i;
                    while i < b.len() && is_ident_byte(b[i]) {
                        i += 1;
                    }
                    push(&mut tokens, Tok::Ident(src[start..i].to_string()), tl);
                }
                line_has_code = true;
            }
            c => {
                push(&mut tokens, Tok::Punct(c as char), line);
                i += 1;
                line_has_code = true;
            }
        }
    }

    mark_test_regions(&mut tokens, &mut allows);
    LexFile { tokens, allows }
}

fn push(tokens: &mut Vec<Token>, tok: Tok, line: u32) {
    tokens.push(Token { tok, line, in_test: false });
}

fn is_ident_start(c: u8) -> bool {
    c == b'_' || (c as char).is_ascii_alphabetic()
}

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || (c as char).is_ascii_alphanumeric()
}

/// Skip a cooked string body starting just past the opening quote.
/// Returns the index past the closing quote.
fn skip_cooked_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2, // escape: skip the escaped byte too
            b'"' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// At a `'`: char literal or lifetime? `'\…'` and `'x'` are chars;
/// `'ident` (no closing quote right after one char) is a lifetime.
fn is_char_literal(b: &[u8], i: usize) -> bool {
    match b.get(i + 1) {
        Some(b'\\') => true,
        Some(&c) if is_ident_byte(c) => b.get(i + 2) == Some(&b'\''),
        Some(_) => true, // '(' etc. — punctuation chars like '[' or ' '
        None => false,
    }
}

/// Skip a char-literal body starting just past the opening quote.
fn skip_char_literal(b: &[u8], mut i: usize) -> usize {
    if b.get(i) == Some(&b'\\') {
        i += 2;
        // \x41 / \u{…} escapes: run to the closing quote.
        while i < b.len() && b[i] != b'\'' {
            i += 1;
        }
        return (i + 1).min(b.len());
    }
    i += 1; // the char itself
    if b.get(i) == Some(&b'\'') {
        i += 1;
    }
    i
}

fn skip_number(b: &[u8], mut i: usize) -> usize {
    // Digits + ident bytes cover hex/suffixes; a dot joins only when
    // followed by a digit so `0..len` stays three tokens.
    while i < b.len() {
        if is_ident_byte(b[i]) {
            i += 1;
        } else if b[i] == b'.'
            && b.get(i + 1).is_some_and(|&c| c.is_ascii_digit())
            && b.get(i.wrapping_sub(1)).is_some_and(|&c| c.is_ascii_digit())
        {
            i += 1;
        } else {
            break;
        }
    }
    i
}

/// If position `i` starts a raw or byte string (`r"`, `r#…#"`, `b"`,
/// `br#…`), consume it and return the index past its end.
fn raw_or_byte_string_end(b: &[u8], i: usize, line: &mut u32) -> Option<usize> {
    let mut j = i;
    let mut raw = false;
    match b[j] {
        b'r' => {
            raw = true;
            j += 1;
        }
        b'b' => {
            j += 1;
            if b.get(j) == Some(&b'r') {
                raw = true;
                j += 1;
            }
        }
        _ => return None,
    }
    if raw {
        let mut hashes = 0usize;
        while b.get(j) == Some(&b'#') {
            hashes += 1;
            j += 1;
        }
        if b.get(j) != Some(&b'"') {
            return None; // plain ident starting with r/br (e.g. `ranges`)
        }
        j += 1;
        // Scan for `"` followed by `hashes` hashes.
        while j < b.len() {
            if b[j] == b'\n' {
                *line += 1;
                j += 1;
                continue;
            }
            if b[j] == b'"' {
                let mut k = j + 1;
                let mut seen = 0usize;
                while seen < hashes && b.get(k) == Some(&b'#') {
                    seen += 1;
                    k += 1;
                }
                if seen == hashes {
                    return Some(k);
                }
            }
            j += 1;
        }
        Some(j)
    } else {
        // b"…" cooked byte string.
        if b.get(j) != Some(&b'"') {
            return None;
        }
        Some(skip_cooked_string(b, j + 1, line))
    }
}

/// Parse a lint directive out of a line comment's text.
fn parse_directive(comment: &str, line: u32, own_line: bool) -> Option<Allow> {
    let t = comment.trim_start_matches('/').trim();
    let rest = t.strip_prefix(DIRECTIVE)?.trim();
    let rest = rest.strip_prefix("allow")?.trim_start();
    let rest = rest.strip_prefix('(')?;
    let close = rest.find(')')?;
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    let tail = rest[close + 1..].trim();
    let has_reason = match tail.strip_prefix("--") {
        Some(reason) => !reason.trim().is_empty(),
        None => false,
    };
    Some(Allow { line, rules, has_reason, own_line, in_test: false })
}

/// Mark tokens (and allows) inside test regions: an item annotated
/// `#[cfg(test)]` / `#[test]`, or a `mod tests`/`mod test` body.
fn mark_test_regions(tokens: &mut [Token], allows: &mut [Allow]) {
    let mut depth = 0i32;
    // Stack of depths at which a test region's `{` opened.
    let mut regions: Vec<i32> = Vec::new();
    let mut pending = false;
    let mut region_lines: Vec<(u32, u32)> = Vec::new();
    let mut open_line = 0u32;

    let mut i = 0usize;
    while i < tokens.len() {
        let in_test = !regions.is_empty();
        tokens[i].in_test = in_test;
        match &tokens[i].tok {
            Tok::Punct('#') => {
                // Attribute: #[ … ] (or #![ … ]); inspect its idents.
                let mut j = i + 1;
                if tokens.get(j).map(|t| t.is_punct('!')) == Some(true) {
                    j += 1;
                }
                if tokens.get(j).map(|t| t.is_punct('[')) == Some(true) {
                    let (end, is_test_attr) = scan_attr(tokens, j);
                    if is_test_attr && !in_test {
                        pending = true;
                        open_line = tokens[i].line;
                    }
                    for t in tokens[i..end.min(tokens.len())].iter_mut() {
                        t.in_test = in_test;
                    }
                    i = end;
                    continue;
                }
            }
            Tok::Ident(s) if s == "mod" => {
                if let Some(Tok::Ident(name)) = tokens.get(i + 1).map(|t| &t.tok) {
                    if (name == "tests" || name == "test") && !in_test {
                        pending = true;
                        open_line = tokens[i].line;
                    }
                }
            }
            Tok::Punct('{') => {
                depth += 1;
                if pending {
                    regions.push(depth);
                    pending = false;
                    tokens[i].in_test = true;
                }
            }
            Tok::Punct('}') => {
                if regions.last() == Some(&depth) {
                    regions.pop();
                    region_lines.push((open_line, tokens[i].line));
                    tokens[i].in_test = true;
                }
                depth -= 1;
            }
            Tok::Punct(';') if pending && regions.is_empty() => {
                // `#[cfg(test)] use …;` — attribute on a bodyless item.
                pending = false;
            }
            _ => {}
        }
        i += 1;
    }
    // Regions still open at EOF run to the end.
    if !regions.is_empty() {
        region_lines.push((open_line, u32::MAX));
    }
    for a in allows.iter_mut() {
        a.in_test =
            region_lines.iter().any(|&(s, e)| a.line >= s && a.line <= e);
    }
}

/// Scan an attribute starting at its `[`; returns (index past the
/// closing `]`, whether it marks a test region). Test markers:
/// `#[test]`, `#[cfg(test)]`, `#[cfg(any/all(… test …))]`.
fn scan_attr(tokens: &[Token], open: usize) -> (usize, bool) {
    let mut depth = 0i32;
    let mut is_cfg = false;
    let mut has_test = false;
    let mut first_ident: Option<&str> = None;
    let mut j = open;
    while j < tokens.len() {
        match &tokens[j].tok {
            Tok::Punct('[') => depth += 1,
            Tok::Punct(']') => {
                depth -= 1;
                if depth == 0 {
                    j += 1;
                    break;
                }
            }
            Tok::Ident(s) => {
                if first_ident.is_none() {
                    first_ident = Some(s);
                }
                if s == "cfg" {
                    is_cfg = true;
                }
                if s == "test" {
                    has_test = true;
                }
            }
            _ => {}
        }
        j += 1;
    }
    let marks_test = match first_ident {
        Some("test") => true,
        Some("cfg") => is_cfg && has_test,
        _ => false,
    };
    (j, marks_test)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(f: &LexFile) -> Vec<String> {
        f.tokens
            .iter()
            .filter_map(|t| t.ident().map(|s| s.to_string()))
            .collect()
    }

    #[test]
    fn raw_string_hides_lock_calls() {
        // The raw string contains `"].lock()"` — it must lex as ONE
        // StrLit token; no `lock` identifier may escape it.
        let src = r####"let s = r#"x"].lock()"y"#; a.lock();"####;
        let f = lex(src);
        let ids = idents(&f);
        assert_eq!(ids, vec!["let", "s", "a", "lock"]);
        let strs = f.tokens.iter().filter(|t| t.tok == Tok::StrLit).count();
        assert_eq!(strs, 1, "raw string is a single token");
    }

    #[test]
    fn nested_block_comments_are_skipped() {
        let src = "a /* outer /* inner.lock() */ still comment */ b";
        let ids = idents(&lex(src));
        assert_eq!(ids, vec!["a", "b"]);
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let src = "let c: char = 'x'; fn f<'a>(v: &'a [u8]) { v.get('['); }";
        let f = lex(src);
        let chars = f.tokens.iter().filter(|t| t.tok == Tok::CharLit).count();
        let lifes = f.tokens.iter().filter(|t| t.tok == Tok::Lifetime).count();
        assert_eq!(chars, 2, "'x' and '[' are char literals");
        assert_eq!(lifes, 2, "<'a> and &'a are lifetimes");
    }

    #[test]
    fn escaped_quotes_and_byte_strings() {
        let src = r#"let a = "s\"t.lock()"; let b = b"x.lock()"; c.lock();"#;
        let ids = idents(&lex(src));
        assert_eq!(ids, vec!["let", "a", "let", "b", "c", "lock"]);
    }

    #[test]
    fn cfg_test_region_tracking_nests() {
        let src = "
fn live() { x.lock(); }
#[cfg(test)]
mod tests {
    fn helper() { y.lock(); }
    #[cfg(test)]
    mod inner { fn g() { z.lock(); } }
    fn after_inner() { w.lock(); }
}
fn live2() { v.lock(); }
";
        let f = lex(src);
        let lock_of = |name: &str| {
            f.tokens
                .iter()
                .position(|t| t.ident() == Some(name))
                .map(|p| f.tokens[p].in_test)
                .unwrap()
        };
        assert!(!lock_of("x"), "top-level fn is live code");
        assert!(lock_of("y"), "mod tests body is a test region");
        assert!(lock_of("z"), "nested cfg(test) stays a test region");
        assert!(lock_of("w"), "after the nested mod, still in outer region");
        assert!(!lock_of("v"), "code after the region is live again");
    }

    #[test]
    fn test_attr_marks_single_fn() {
        let src = "
#[test]
fn a_test() { x.unwrap(); }
fn live() { y.unwrap(); }
";
        let f = lex(src);
        let pos = |name: &str| f.tokens.iter().position(|t| t.ident() == Some(name)).unwrap();
        assert!(f.tokens[pos("x")].in_test);
        assert!(!f.tokens[pos("y")].in_test);
    }

    #[test]
    fn cfg_test_on_bodyless_item_does_not_leak() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn live() { x.unwrap(); }";
        let f = lex(src);
        let pos = f.tokens.iter().position(|t| t.ident() == Some("x")).unwrap();
        assert!(!f.tokens[pos].in_test);
    }

    #[test]
    fn directive_parsing_variants() {
        let src = "
let a = 1; // compeft-lint: allow(no-map-order) -- reduction is order-free
// compeft-lint: allow(lock-order, no-wall-clock) -- bench scaffolding
// compeft-lint: allow(no-panic-in-parse)
// compeft-lint: allow(no-panic-in-parse) --
// not a directive
";
        let f = lex(src);
        assert_eq!(f.allows.len(), 4);
        assert!(f.allows[0].has_reason && !f.allows[0].own_line);
        assert!(f.allows[0].covers(2) && !f.allows[0].covers(3));
        assert_eq!(f.allows[1].rules, vec!["lock-order", "no-wall-clock"]);
        assert!(f.allows[1].own_line && f.allows[1].covers(4));
        assert!(!f.allows[2].has_reason, "bare allow");
        assert!(!f.allows[3].has_reason, "empty reason is bare");
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        let ids: Vec<String> = idents(&lex("for i in 0..table.len() {}"));
        assert_eq!(ids, vec!["for", "i", "in", "table", "len"]);
    }
}
