//! `compeft-lint`: an in-repo, dependency-free static analysis pass
//! enforcing this repo's determinism / panic-safety / lock-discipline
//! contracts. Runs three ways: `compeft lint` (CLI, non-zero exit on
//! violations), the tier-1 gate in `tests/lint.rs`, and a dedicated CI
//! step.
//!
//! Rules (each motivated by a shipped bug — see the README section
//! "Static analysis & determinism contract"):
//!
//! | rule id                   | where it lives        |
//! |---------------------------|-----------------------|
//! | `no-panic-in-parse`       | [`rules`]             |
//! | `no-map-order`            | [`rules`]             |
//! | `no-wall-clock`           | [`rules`]             |
//! | `no-unchecked-wire-alloc` | [`rules`]             |
//! | `lock-order`              | [`lockorder`]         |
//!
//! Escape hatch: `// compeft-lint: allow(rule-id) -- <reason>` on the
//! offending line, or alone on the line above it. The reason is
//! mandatory: a bare `allow` is itself reported
//! (`allow-without-reason`), as is an `allow` naming an id that does
//! not exist (`unknown-rule-id`) — so suppressions can't rot silently.

pub mod lexer;
pub mod lockorder;
pub mod rules;

use std::fmt;
use std::path::{Path, PathBuf};

/// The allowable rule ids (what `allow(...)` may name).
pub const KNOWN_RULES: &[&str] = &[
    rules::NO_PANIC,
    rules::NO_MAP_ORDER,
    rules::NO_WALL_CLOCK,
    lockorder::RULE,
    rules::NO_WIRE_ALLOC,
];

/// Meta-rules emitted by the allow machinery itself (not allowable).
pub const ALLOW_NO_REASON: &str = "allow-without-reason";
pub const UNKNOWN_RULE: &str = "unknown-rule-id";

/// One `file:line [rule-id] message` finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl Diagnostic {
    pub fn new(file: &str, line: u32, rule: &'static str, msg: String) -> Diagnostic {
        Diagnostic { file: file.to_string(), line, rule, msg }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Analyze in-memory sources (path, text). The path decides which
/// rules and scoping tables apply, so fixtures exercise production
/// configuration. Returns unsuppressed diagnostics, sorted by
/// (file, line, rule).
pub fn analyze_sources(files: &[(String, String)]) -> Vec<Diagnostic> {
    let lexed: Vec<(String, lexer::LexFile)> = files
        .iter()
        .map(|(p, s)| (p.clone(), lexer::lex(s)))
        .collect();
    let mut raw: Vec<Diagnostic> = Vec::new();
    for (path, lf) in &lexed {
        raw.extend(rules::check_file(path, lf));
    }
    raw.extend(lockorder::check(&lexed));

    let mut out: Vec<Diagnostic> = Vec::new();
    for d in raw {
        let allows = lexed
            .iter()
            .find(|(p, _)| *p == d.file)
            .map(|(_, lf)| lf.allows.as_slice())
            .unwrap_or(&[]);
        let suppressed = allows.iter().any(|a| {
            a.has_reason && a.covers(d.line) && a.rules.iter().any(|r| r == d.rule)
        });
        if !suppressed {
            out.push(d);
        }
    }
    // The allow machinery's own violations: reasonless or unknown-id
    // directives are findings wherever they appear (test code too —
    // a rotten suppression is a lie regardless of where it sits).
    for (path, lf) in &lexed {
        for a in &lf.allows {
            if !a.has_reason {
                out.push(Diagnostic::new(
                    path,
                    a.line,
                    ALLOW_NO_REASON,
                    "allow without a `-- <reason>` justification".to_string(),
                ));
            }
            for r in &a.rules {
                if !KNOWN_RULES.contains(&r.as_str()) {
                    out.push(Diagnostic::new(
                        path,
                        a.line,
                        UNKNOWN_RULE,
                        format!("allow names unknown rule `{r}` (known: {})",
                            KNOWN_RULES.join(", ")),
                    ));
                }
            }
        }
    }
    out.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    out
}

/// Lint every `.rs` file under `<repo_root>/rust/src`, in sorted path
/// order. `repo_root` is normally `env!("CARGO_MANIFEST_DIR")`.
pub fn lint_tree(repo_root: &Path) -> anyhow::Result<Vec<Diagnostic>> {
    let src = repo_root.join("rust").join("src");
    let mut paths: Vec<PathBuf> = Vec::new();
    collect_rs(&src, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", p.display()))?;
        // Report paths relative to the repo root, with `/` separators.
        let rel = p
            .strip_prefix(repo_root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push((rel, text));
    }
    Ok(analyze_sources(&files))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| anyhow::anyhow!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(path: &str, src: &str) -> Vec<Diagnostic> {
        analyze_sources(&[(path.to_string(), src.to_string())])
    }

    // Per-rule allow quadruples: the must-fire/must-pass halves live in
    // the rule modules; here each rule id gets (a) allow-with-reason
    // accepted and (b) bare allow rejected.

    #[test]
    fn allow_with_reason_suppresses_each_rule() {
        let cases: &[(&str, &str, &str)] = &[
            (
                rules::NO_PANIC,
                "rust/src/compeft/format.rs",
                "fn f(b: &[u8]) -> u8 {\n    // compeft-lint: allow(no-panic-in-parse) -- caller validated len\n    b[0]\n}",
            ),
            (
                rules::NO_MAP_ORDER,
                "rust/src/coordinator/cache.rs",
                "struct T {\n    // compeft-lint: allow(no-map-order) -- keyed access only\n    entries: HashMap<String, u32>,\n}",
            ),
            (
                rules::NO_WALL_CLOCK,
                "rust/src/workload/sim.rs",
                "fn f() {\n    // compeft-lint: allow(no-wall-clock) -- offset cancels in us_of\n    let t = Instant::now();\n}",
            ),
            (
                rules::NO_WIRE_ALLOC,
                "rust/src/util/npz.rs",
                "fn f(n: usize) -> Vec<u8> {\n    // compeft-lint: allow(no-unchecked-wire-alloc) -- n <= archive len by construction\n    Vec::with_capacity(n)\n}",
            ),
            (
                lockorder::RULE,
                "rust/src/coordinator/pipeline.rs",
                "impl S {\n    fn f(&self) {\n        let i = self.inner.lock().unwrap();\n        // compeft-lint: allow(lock-order) -- fixture: documented exception\n        let p = self.plan.lock().unwrap();\n    }\n}",
            ),
        ];
        for (rule, path, src) in cases {
            let d = run(path, src);
            assert!(d.is_empty(), "{rule}: {d:?}");
            // Sanity: without the allow the same snippet fires.
            let stripped: String = src
                .lines()
                .filter(|l| !l.contains("compeft-lint"))
                .collect::<Vec<_>>()
                .join("\n");
            let d = run(path, &stripped);
            assert!(d.iter().any(|d| d.rule == *rule), "{rule}: {d:?}");
        }
    }

    #[test]
    fn bare_allow_is_rejected_and_does_not_suppress() {
        let d = run(
            "rust/src/compeft/format.rs",
            "fn f(b: &[u8]) -> u8 {\n    // compeft-lint: allow(no-panic-in-parse)\n    b[0]\n}",
        );
        let rules_seen: Vec<&str> = d.iter().map(|d| d.rule).collect();
        assert!(rules_seen.contains(&ALLOW_NO_REASON), "{d:?}");
        assert!(rules_seen.contains(&rules::NO_PANIC), "{d:?}");
    }

    #[test]
    fn empty_reason_counts_as_bare() {
        let d = run(
            "rust/src/compeft/format.rs",
            "fn f(b: &[u8]) -> u8 {\n    // compeft-lint: allow(no-panic-in-parse) --\n    b[0]\n}",
        );
        assert!(d.iter().any(|d| d.rule == ALLOW_NO_REASON), "{d:?}");
    }

    #[test]
    fn unknown_rule_id_in_allow_is_reported() {
        let d = run(
            "rust/src/compeft/format.rs",
            "// compeft-lint: allow(no-such-rule) -- oops\nfn f() {}",
        );
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, UNKNOWN_RULE);
        assert!(d[0].msg.contains("no-such-rule"), "{}", d[0].msg);
    }

    #[test]
    fn trailing_allow_covers_its_own_line() {
        let d = run(
            "rust/src/compeft/format.rs",
            "fn f(b: &[u8]) -> u8 { b[0] } // compeft-lint: allow(no-panic-in-parse) -- len checked by caller",
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn own_line_allow_does_not_leak_past_next_line() {
        let d = run(
            "rust/src/compeft/format.rs",
            "fn f(b: &[u8]) -> u8 {\n    // compeft-lint: allow(no-panic-in-parse) -- only covers next line\n    let a = b[0];\n    let c = b[1];\n    a + c\n}",
        );
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 4);
    }

    #[test]
    fn allow_for_one_rule_does_not_suppress_another() {
        let d = run(
            "rust/src/util/npz.rs",
            "fn f(n: usize) -> u8 {\n    // compeft-lint: allow(no-unchecked-wire-alloc) -- wrong rule\n    let b = vec![0u8; 4];\n    b[n]\n}",
        );
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, rules::NO_PANIC);
    }

    #[test]
    fn multi_rule_allow_suppresses_both() {
        let d = run(
            "rust/src/util/npz.rs",
            "fn f(b: &[u8], n: usize) -> Vec<u8> {\n    // compeft-lint: allow(no-panic-in-parse, no-unchecked-wire-alloc) -- n validated against b.len() by caller\n    { let _ = b[0]; Vec::with_capacity(n) }\n}",
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn diagnostics_format_as_file_line_rule() {
        let d = Diagnostic::new("rust/src/a.rs", 7, rules::NO_PANIC, "msg".into());
        assert_eq!(d.to_string(), "rust/src/a.rs:7 [no-panic-in-parse] msg");
    }

    #[test]
    fn diagnostics_are_sorted_and_stable() {
        let d = run(
            "rust/src/util/npz.rs",
            "fn f(b: &[u8]) -> u8 { b[1] + b[0] }\nfn g(b: &[u8]) -> u8 { b[0] }",
        );
        assert_eq!(d.len(), 3);
        assert!(d.windows(2).all(|w| (w[0].line, w[0].rule) <= (w[1].line, w[1].rule)));
    }
}
