//! Per-file lint rules for `compeft-lint`.
//!
//! Four of the five rules live here (the cross-file `lock-order` pass
//! is `analysis::lockorder`). Each is grounded in a shipped bug or a
//! standing contract of this repo:
//!
//! - `no-panic-in-parse` — wire parsing must return `Err`, never
//!   panic (PR 2: a `copy_from_slice` length panic in decode killed
//!   the serving engine for all clients). Bans `.unwrap()`,
//!   `.expect()`, `panic!`-family macros, and direct `[...]` indexing
//!   in the wire-parse modules.
//! - `no-map-order` — no `HashMap`/`HashSet` in non-test coordinator
//!   or workload code without an order-insensitivity justification
//!   (PR 4: `Batcher::pick` followed `HashMap` iteration order and
//!   starved queues). Keyed on the type name: hash containers are
//!   only admissible where every iteration over them is provably
//!   order-insensitive, and that argument belongs next to the field.
//! - `no-wall-clock` — `Instant::now`/`SystemTime::now` only in the
//!   allowlisted wall-time modules, so the virtual-clock purity of
//!   the workload/sim paths can't silently regress.
//! - `no-unchecked-wire-alloc` — `with_capacity`/`vec![x; n]` in
//!   parse modules must have a nearby bounds check (a `bail!`/
//!   `ensure!`/`checked_mul`/... within the preceding lines) or an
//!   annotation, so a hostile length field can't drive allocation.

use super::lexer::{LexFile, Tok, Token};
use super::Diagnostic;

pub const NO_PANIC: &str = "no-panic-in-parse";
pub const NO_MAP_ORDER: &str = "no-map-order";
pub const NO_WALL_CLOCK: &str = "no-wall-clock";
pub const NO_WIRE_ALLOC: &str = "no-unchecked-wire-alloc";

/// Modules that parse wire/disk bytes: a malformed or hostile input
/// must surface as `Err`, not a panic.
const PARSE_FILES: &[&str] = &[
    "compeft/format.rs",
    "compeft/golomb.rs",
    "compeft/bitmask.rs",
    "compeft/payload.rs",
    "coordinator/archive.rs",
    "util/npz.rs",
];

/// Modules whose job is measuring or pacing real time.
const WALL_CLOCK_FILES: &[&str] = &[
    "coordinator/loader.rs",
    "coordinator/server.rs",
    "coordinator/transport.rs",
    "util/bench.rs",
    "main.rs",
];

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Idents whose presence in the preceding lines counts as a bounds
/// check for `no-unchecked-wire-alloc`.
const BOUNDS_EVIDENCE: &[&str] =
    &["bail", "ensure", "checked_mul", "checked_add", "checked_sub", "min", "take"];

/// Lines of lookback for the bounds-evidence scan.
const EVIDENCE_WINDOW: u32 = 10;

fn is_parse_file(path: &str) -> bool {
    PARSE_FILES.iter().any(|f| path.ends_with(f))
}

fn is_map_order_scope(path: &str) -> bool {
    path.contains("coordinator/") || path.contains("workload/")
}

fn is_wall_clock_file(path: &str) -> bool {
    WALL_CLOCK_FILES.iter().any(|f| path.ends_with(f)) || path.contains("analysis/")
}

/// Run all per-file rules over one lexed file.
pub fn check_file(path: &str, lexed: &LexFile) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let toks = &lexed.tokens;
    let in_use = use_statement_mask(toks);
    let parse = is_parse_file(path);
    let map_scope = is_map_order_scope(path);
    let wall = !is_wall_clock_file(path);
    for (i, t) in toks.iter().enumerate() {
        if t.in_test {
            continue;
        }
        match &t.tok {
            Tok::Ident(id) => {
                if parse {
                    no_panic_ident(path, toks, i, id, &mut diags);
                    no_wire_alloc_ident(path, toks, i, id, &mut diags);
                }
                if map_scope
                    && !in_use[i]
                    && (id == "HashMap" || id == "HashSet")
                {
                    diags.push(Diagnostic::new(
                        path,
                        t.line,
                        NO_MAP_ORDER,
                        format!(
                            "`{id}` in coordinator/workload code: use `BTreeMap`/\
                             sorted iteration, or annotate why every iteration \
                             over it is order-insensitive"
                        ),
                    ));
                }
                if wall
                    && (id == "Instant" || id == "SystemTime")
                    && is_punct(toks, i + 1, ':')
                    && is_punct(toks, i + 2, ':')
                    && ident_at(toks, i + 3) == Some("now")
                {
                    diags.push(Diagnostic::new(
                        path,
                        t.line,
                        NO_WALL_CLOCK,
                        format!(
                            "`{id}::now` outside the wall-time modules: sim/\
                             workload paths must stay virtual-clock pure"
                        ),
                    ));
                }
            }
            Tok::Punct('[') if parse && i > 0 => {
                // Index expression: `expr[...]`. An opening bracket
                // after a value (ident, `)`, `]`, or `?`) indexes; after
                // `#` it's an attribute, after `!` a macro, after type
                // or grouping punctuation it's an array/slice type or
                // literal.
                let indexes = match &toks[i - 1].tok {
                    Tok::Ident(id) => !matches!(
                        id.as_str(),
                        "mut" | "ref" | "dyn" | "in" | "as" | "return" | "else"
                    ),
                    Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('?') => true,
                    _ => false,
                };
                if indexes {
                    diags.push(Diagnostic::new(
                        path,
                        t.line,
                        NO_PANIC,
                        "direct `[...]` indexing in wire-parse code can panic on \
                         malformed input; use `get`/`get_mut` or validate and \
                         annotate"
                            .to_string(),
                    ));
                }
            }
            _ => {}
        }
    }
    diags
}

fn no_panic_ident(
    path: &str,
    toks: &[Token],
    i: usize,
    id: &str,
    diags: &mut Vec<Diagnostic>,
) {
    if (id == "unwrap" || id == "expect")
        && i > 0
        && toks[i - 1].is_punct('.')
        && is_punct(toks, i + 1, '(')
    {
        diags.push(Diagnostic::new(
            path,
            toks[i].line,
            NO_PANIC,
            format!("`.{id}()` in wire-parse code: return `Err` instead"),
        ));
    }
    if PANIC_MACROS.contains(&id) && is_punct(toks, i + 1, '!') {
        diags.push(Diagnostic::new(
            path,
            toks[i].line,
            NO_PANIC,
            format!("`{id}!` in wire-parse code: return `Err` instead"),
        ));
    }
}

fn no_wire_alloc_ident(
    path: &str,
    toks: &[Token],
    i: usize,
    id: &str,
    diags: &mut Vec<Diagnostic>,
) {
    let t = &toks[i];
    if id == "with_capacity" && is_punct(toks, i + 1, '(') {
        // `with_capacity(<literal>)` is fine; anything computed needs
        // nearby evidence of a bounds check.
        let const_arg = matches!(toks.get(i + 2).map(|t| &t.tok), Some(Tok::Num))
            && is_punct(toks, i + 3, ')');
        if !const_arg && !has_bounds_evidence(toks, t.line) {
            diags.push(Diagnostic::new(
                path,
                t.line,
                NO_WIRE_ALLOC,
                "`with_capacity` with a computed size in wire-parse code: \
                 bound it (bail!/ensure!/checked_mul/...) within the \
                 preceding lines, or annotate"
                    .to_string(),
            ));
        }
    }
    if id == "vec" && is_punct(toks, i + 1, '!') && is_punct(toks, i + 2, '[') {
        // `vec![elem; n]`: find the `;` at bracket depth 1.
        let mut depth = 0i32;
        let mut j = i + 2;
        let mut semi: Option<usize> = None;
        while j < toks.len() {
            match toks[j].tok {
                Tok::Punct('[') => depth += 1,
                Tok::Punct(']') => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                Tok::Punct(';') if depth == 1 => semi = Some(j),
                _ => {}
            }
            j += 1;
        }
        if let Some(s) = semi {
            let const_len = matches!(toks.get(s + 1).map(|t| &t.tok), Some(Tok::Num))
                && is_punct(toks, s + 2, ']');
            if !const_len && !has_bounds_evidence(toks, t.line) {
                diags.push(Diagnostic::new(
                    path,
                    t.line,
                    NO_WIRE_ALLOC,
                    "`vec![_; n]` with a computed length in wire-parse code: \
                     bound it (bail!/ensure!/checked_mul/...) within the \
                     preceding lines, or annotate"
                        .to_string(),
                ));
            }
        }
    }
}

/// True when a bounds-check ident appears within the preceding
/// [`EVIDENCE_WINDOW`] lines (inclusive of the allocation line).
fn has_bounds_evidence(toks: &[Token], line: u32) -> bool {
    let lo = line.saturating_sub(EVIDENCE_WINDOW);
    toks.iter().any(|t| {
        t.line >= lo
            && t.line <= line
            && t.ident().is_some_and(|id| BOUNDS_EVIDENCE.contains(&id))
    })
}

/// `mask[i]` is true for tokens inside a `use ...;` item.
fn use_statement_mask(toks: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0;
    while i < toks.len() {
        let is_use = toks[i].ident() == Some("use")
            && (i == 0 || !toks[i - 1].is_punct('.') && !toks[i - 1].is_punct(':'));
        if is_use {
            while i < toks.len() && !toks[i].is_punct(';') {
                mask[i] = true;
                i += 1;
            }
        }
        i += 1;
    }
    mask
}

fn ident_at(toks: &[Token], i: usize) -> Option<&str> {
    toks.get(i).and_then(|t| t.ident())
}

fn is_punct(toks: &[Token], i: usize, c: char) -> bool {
    toks.get(i).is_some_and(|t| t.is_punct(c))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn run(path: &str, src: &str) -> Vec<Diagnostic> {
        check_file(path, &lex(src))
    }

    fn rules(d: &[Diagnostic]) -> Vec<&str> {
        d.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn no_panic_fires_on_unwrap_expect_and_macros() {
        let d = run(
            "rust/src/compeft/format.rs",
            r#"
            fn parse(b: &[u8]) -> Result<u16> {
                let x = b.first().unwrap();
                let y = b.get(1).expect("hdr");
                if *x == 0 { panic!("zero"); }
                unreachable!("tag")
            }
            "#,
        );
        assert_eq!(rules(&d), vec![NO_PANIC; 4], "{d:?}");
        assert_eq!(d[0].line, 3);
    }

    #[test]
    fn no_panic_fires_on_direct_indexing() {
        let d = run(
            "rust/src/util/npz.rs",
            "fn f(b: &[u8]) -> u8 { let w = b[0]; let s = &b[1..3]; w + s[0] }",
        );
        assert_eq!(rules(&d), vec![NO_PANIC; 3], "{d:?}");
    }

    #[test]
    fn no_panic_passes_on_checked_access_and_types() {
        // `get`-based access, array types, attributes, macro brackets,
        // and array literals must not fire.
        let d = run(
            "rust/src/compeft/golomb.rs",
            r#"
            #[derive(Clone)]
            struct H { buf: [u8; 4] }
            fn f(b: &[u8]) -> Option<u8> {
                let v: Vec<u8> = vec![1, 2];
                let _arr = [0u8, 1];
                b.get(0).copied()
            }
            "#,
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn no_panic_ignores_test_regions_and_other_files() {
        let in_test = run(
            "rust/src/compeft/format.rs",
            r#"
            #[cfg(test)]
            mod tests {
                fn t(b: &[u8]) { let _ = b[0]; b.first().unwrap(); }
            }
            "#,
        );
        assert!(in_test.is_empty(), "{in_test:?}");
        let other = run("rust/src/coordinator/batcher.rs", "fn f(b: &[u8]) { b[0]; }");
        assert!(other.iter().all(|d| d.rule != NO_PANIC), "{other:?}");
    }

    #[test]
    fn map_order_fires_in_scope_and_skips_use_lines() {
        let d = run(
            "rust/src/coordinator/cache.rs",
            r#"
            use std::collections::HashMap;
            struct T { entries: HashMap<String, u32> }
            "#,
        );
        assert_eq!(rules(&d), vec![NO_MAP_ORDER], "{d:?}");
        assert_eq!(d[0].line, 3);
    }

    #[test]
    fn map_order_passes_btreemap_and_out_of_scope() {
        let ok = run(
            "rust/src/coordinator/registry.rs",
            "struct R { by_id: BTreeMap<String, u32> }",
        );
        assert!(ok.is_empty(), "{ok:?}");
        let other = run(
            "rust/src/runtime/bundle.rs",
            "struct C { exes: HashMap<u32, u32> }",
        );
        assert!(other.is_empty(), "{other:?}");
    }

    #[test]
    fn wall_clock_fires_outside_allowlist_only() {
        let d = run(
            "rust/src/workload/sim.rs",
            "fn f() { let t = Instant::now(); }",
        );
        assert_eq!(rules(&d), vec![NO_WALL_CLOCK], "{d:?}");
        let fn_ref = run(
            "rust/src/workload/sim.rs",
            "fn f(w: &mut Option<Instant>) { w.get_or_insert_with(Instant::now); }",
        );
        assert_eq!(rules(&fn_ref), vec![NO_WALL_CLOCK], "{fn_ref:?}");
        let ok = run(
            "rust/src/coordinator/loader.rs",
            "fn f() { let t = Instant::now(); let s = SystemTime::now(); }",
        );
        assert!(ok.is_empty(), "{ok:?}");
        // Bare type mentions (params, fields) are fine anywhere.
        let ty = run(
            "rust/src/coordinator/batcher.rs",
            "struct P { enqueued: Instant } fn f(now: Instant) {}",
        );
        assert!(ty.iter().all(|d| d.rule != NO_WALL_CLOCK), "{ty:?}");
    }

    #[test]
    fn wire_alloc_fires_without_evidence_and_passes_with() {
        let bad = run(
            "rust/src/coordinator/archive.rs",
            "fn f(n: usize) -> Vec<u8> { Vec::with_capacity(n) }",
        );
        assert_eq!(rules(&bad), vec![NO_WIRE_ALLOC], "{bad:?}");
        let good = run(
            "rust/src/coordinator/archive.rs",
            r#"
            fn f(n: usize) -> Result<Vec<u8>> {
                if n > MAX { bail!("too big"); }
                Ok(Vec::with_capacity(n))
            }
            "#,
        );
        assert!(good.is_empty(), "{good:?}");
    }

    #[test]
    fn wire_alloc_vec_macro_and_const_sizes() {
        let bad = run(
            "rust/src/util/npz.rs",
            "fn f(n: usize) -> Vec<u8> { vec![0u8; n] }",
        );
        assert_eq!(rules(&bad), vec![NO_WIRE_ALLOC], "{bad:?}");
        // Constant sizes and list-form vec! are fine.
        let good = run(
            "rust/src/util/npz.rs",
            "fn f() -> Vec<u8> { let a = Vec::with_capacity(64); vec![0u8; 16]; vec![1, 2, 3] }",
        );
        assert!(good.is_empty(), "{good:?}");
    }
}
