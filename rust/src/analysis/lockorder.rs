//! `lock-order`: static lock-acquisition-order checking.
//!
//! The coordinator holds several mutexes (batcher queues, prefetch
//! plan, staging area, CPU cache tier, transport link state, pool
//! channels, executable cache, metrics). A deadlock needs two threads
//! acquiring two of them in opposite orders — so we declare a total
//! rank order (the table in [`LOCK_CLASSES`], mirrored at runtime by
//! `util::sync::rank`) and statically reject any code path that
//! acquires a lower-ranked lock while holding a higher-ranked one.
//!
//! The pass works on the lexer's token stream:
//!
//! 1. index every `fn` (with its `impl` owner) in the scoped files;
//! 2. per function, simulate brace depth to find which guards are
//!    live at each point, recording (a) direct `.lock()` acquisitions
//!    and (b) method calls made while guards are held;
//! 3. resolve calls interprocedurally — `self.m()` through the
//!    enclosing impl, `x.m()` through a receiver-ident → type hint
//!    table, bare `m()` through same-file free functions — and close
//!    each function's may-acquire set transitively;
//! 4. turn every "acquire B while holding A" into an edge A → B and
//!    check each edge against the rank table, plus a belt-and-braces
//!    cycle check over the legal edges.
//!
//! Receivers are matched by field identifier per file (`inner` means
//! the staging area in `pipeline.rs` but the metrics state in
//! `metrics.rs`); an unmatched `.lock()` receiver in a scoped file is
//! itself an error, so new mutexes must be added to the table.

use super::lexer::{LexFile, Tok, Token};
use super::Diagnostic;
use crate::util::sync::rank;

pub const RULE: &str = "lock-order";

/// Declared lock classes and their ranks (must acquire in strictly
/// increasing rank). The runtime twin is `util::sync::rank`; a test
/// below pins the two tables together.
pub const LOCK_CLASSES: &[(&str, u32)] = &[
    ("batcher.queues", rank::BATCHER_QUEUES),
    ("pipeline.plan", rank::PREFETCH_PLAN),
    ("pipeline.staging", rank::STAGING),
    ("cache.cpu_tier", rank::CPU_TIER),
    ("store.epoch", rank::STORE_EPOCH),
    ("store.stats", rank::STORE_STATS),
    ("transport.link", rank::LINK_STATE),
    ("pool.sender", rank::POOL_SENDER),
    ("pool.receiver", rank::POOL_RECEIVER),
    ("runtime.exec_cache", rank::EXEC_CACHE),
    ("metrics.inner", rank::METRICS),
];

/// `.lock()` receiver field ident → lock class, scoped by file suffix
/// ("" matches any file).
const RECEIVER_CLASSES: &[(&str, &str, &str)] = &[
    ("coordinator/batcher.rs", "queues", "batcher.queues"),
    ("coordinator/pipeline.rs", "plan", "pipeline.plan"),
    ("coordinator/pipeline.rs", "inner", "pipeline.staging"),
    ("coordinator/pipeline.rs", "cpu", "cache.cpu_tier"),
    ("coordinator/server.rs", "cpu", "cache.cpu_tier"),
    ("coordinator/store.rs", "epoch", "store.epoch"),
    ("coordinator/store.rs", "stats", "store.stats"),
    ("coordinator/transport.rs", "state", "transport.link"),
    ("util/pool.rs", "tx", "pool.sender"),
    ("util/pool.rs", "rx", "pool.receiver"),
    ("runtime/bundle.rs", "exes", "runtime.exec_cache"),
    ("coordinator/metrics.rs", "inner", "metrics.inner"),
];

/// Receiver variable/field ident → type name, for resolving `x.m()`
/// calls across modules while a lock is held.
const RECEIVER_TYPES: &[(&str, &str)] = &[
    ("batcher", "Batcher"),
    ("staging", "StagingArea"),
    ("metrics", "Metrics"),
    ("pool", "ThreadPool"),
];

/// Method names that forward to the underlying value without locking;
/// skipped when scanning backwards for the receiver field ident
/// (`self.tx.as_ref().expect("...").lock()` resolves to `tx`).
const ADAPTERS: &[&str] = &[
    "as_ref", "as_mut", "as_deref", "expect", "unwrap", "clone", "borrow",
    "borrow_mut",
];

const KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "in", "as", "let",
    "mut", "ref", "move", "pub", "fn", "impl", "use", "mod", "struct",
    "enum", "trait", "where", "unsafe", "dyn", "break", "continue", "else",
    "self", "Self", "super", "crate", "true", "false", "Some", "Ok", "Err",
    "None",
];

/// Files whose lock usage is in scope for this rule.
pub fn in_scope(path: &str) -> bool {
    (path.contains("coordinator/")
        || path.ends_with("util/pool.rs")
        || path.ends_with("runtime/bundle.rs"))
        && !path.contains("analysis/")
}

fn class_of(path: &str, recv: &str) -> Option<usize> {
    for (file, ident, class) in RECEIVER_CLASSES {
        if (file.is_empty() || path.ends_with(file)) && recv == *ident {
            return LOCK_CLASSES.iter().position(|(n, _)| n == class);
        }
    }
    None
}

fn rank_of(class: usize) -> u32 {
    LOCK_CLASSES[class].1
}

/// A function indexed in pass 1.
struct Func {
    owner: Option<String>,
    name: String,
    file: usize,
    /// Token index range of the body, excluding the outer braces.
    body: std::ops::Range<usize>,
}

/// What a function does with locks (pass 2).
#[derive(Default)]
struct Effects {
    /// Directly acquired classes with the held-set at that point.
    acquires: Vec<(usize, u32, Vec<usize>)>, // (class, line, held)
    /// Calls made; `held` is the set of classes held at the call site.
    calls: Vec<(CallKey, u32, Vec<usize>)>, // (callee, line, held)
}

#[derive(Clone, PartialEq, Eq, Hash, Debug)]
enum CallKey {
    /// `x.m()` with a type hint, or `self.m()` via the impl owner.
    Method(String, String),
    /// `m()` resolved against free fns in the same file.
    Free(usize, String),
}

struct HeldLock {
    class: usize,
    guard: Option<String>,
    depth: usize,
    /// Temporary (no `let` binding): released at end of statement.
    temp: bool,
}

/// Run the lock-order rule over lexed files. `files` pairs each path
/// with its lex result; diagnostics point at acquisition sites.
pub fn check(files: &[(String, LexFile)]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut funcs: Vec<Func> = Vec::new();
    for (fi, (path, lexed)) in files.iter().enumerate() {
        if in_scope(path) {
            index_functions(fi, &lexed.tokens, &mut funcs);
        }
    }
    // Extract per-function lock behaviour.
    let mut effects: Vec<Effects> = Vec::new();
    for f in &funcs {
        let (path, lexed) = &files[f.file];
        effects.push(extract(path, &lexed.tokens, f, &mut diags));
    }
    // Resolve call keys to function indices.
    let mut resolved: Vec<Vec<usize>> = Vec::with_capacity(funcs.len());
    for e in &effects {
        let mut callees = Vec::new();
        for (key, _, _) in &e.calls {
            if let Some(ci) = resolve(&funcs, key) {
                callees.push(ci);
            }
        }
        resolved.push(callees);
    }
    // Transitive may-acquire closure per function.
    let mut closure: Vec<Option<Vec<usize>>> = vec![None; funcs.len()];
    for i in 0..funcs.len() {
        close(i, &effects, &resolved, &mut closure, &mut Vec::new());
    }
    // Collect edges: held class -> acquired class.
    struct Edge {
        from: usize,
        to: usize,
        file: usize,
        line: u32,
        via: Option<String>,
    }
    let mut edges: Vec<Edge> = Vec::new();
    for (i, e) in effects.iter().enumerate() {
        let file = funcs[i].file;
        for (class, line, held) in &e.acquires {
            for h in held {
                edges.push(Edge { from: *h, to: *class, file, line: *line, via: None });
            }
        }
        for (key, line, held) in &e.calls {
            if held.is_empty() {
                continue;
            }
            if let Some(ci) = resolve(&funcs, key) {
                let via = format!("{:?}", key);
                for c in closure[ci].as_deref().unwrap_or(&[]) {
                    for h in held {
                        edges.push(Edge {
                            from: *h,
                            to: *c,
                            file,
                            line: *line,
                            via: Some(via.clone()),
                        });
                    }
                }
            }
        }
    }
    // Rank check, deduped per (from, to, site).
    let mut seen = std::collections::BTreeSet::new();
    let mut legal = std::collections::BTreeSet::new();
    for e in &edges {
        if !seen.insert((e.from, e.to, e.file, e.line)) {
            continue;
        }
        let (fname, tname) = (LOCK_CLASSES[e.from].0, LOCK_CLASSES[e.to].0);
        if e.from == e.to {
            diags.push(Diagnostic::new(
                &files[e.file].0,
                e.line,
                RULE,
                format!("re-entrant acquisition of lock class `{fname}`"),
            ));
        } else if rank_of(e.from) >= rank_of(e.to) {
            let via = e.via.as_deref().map(|v| format!(" (via {v})")).unwrap_or_default();
            diags.push(Diagnostic::new(
                &files[e.file].0,
                e.line,
                RULE,
                format!(
                    "acquires `{tname}` (rank {}) while holding `{fname}` (rank {}){via}; \
                     declared order requires strictly increasing rank",
                    rank_of(e.to),
                    rank_of(e.from),
                ),
            ));
        } else {
            legal.insert((e.from, e.to));
        }
    }
    if let Some(cycle) = find_cycle(&legal) {
        let names: Vec<&str> = cycle.iter().map(|&c| LOCK_CLASSES[c].0).collect();
        diags.push(Diagnostic::new(
            &files.first().map(|(p, _)| p.as_str()).unwrap_or("<lock-graph>"),
            0,
            RULE,
            format!("lock acquisition graph has a cycle: {}", names.join(" -> ")),
        ));
    }
    diags
}

fn resolve(funcs: &[Func], key: &CallKey) -> Option<usize> {
    match key {
        CallKey::Method(ty, name) => funcs
            .iter()
            .position(|f| f.owner.as_deref() == Some(ty) && f.name == *name),
        CallKey::Free(file, name) => funcs
            .iter()
            .position(|f| f.file == *file && f.owner.is_none() && f.name == *name),
    }
}

fn close(
    i: usize,
    effects: &[Effects],
    resolved: &[Vec<usize>],
    memo: &mut Vec<Option<Vec<usize>>>,
    stack: &mut Vec<usize>,
) {
    if memo[i].is_some() || stack.contains(&i) {
        return;
    }
    stack.push(i);
    let mut acc: Vec<usize> = effects[i].acquires.iter().map(|(c, _, _)| *c).collect();
    for &ci in &resolved[i] {
        close(ci, effects, resolved, memo, stack);
        if let Some(sub) = &memo[ci] {
            acc.extend_from_slice(sub);
        }
    }
    acc.sort_unstable();
    acc.dedup();
    stack.pop();
    memo[i] = Some(acc);
}

/// DFS cycle detection over the legal-edge set.
fn find_cycle(edges: &std::collections::BTreeSet<(usize, usize)>) -> Option<Vec<usize>> {
    let n = LOCK_CLASSES.len();
    // 0 = unvisited, 1 = on stack, 2 = done
    let mut state = vec![0u8; n];
    let mut path: Vec<usize> = Vec::new();
    fn dfs(
        v: usize,
        edges: &std::collections::BTreeSet<(usize, usize)>,
        state: &mut [u8],
        path: &mut Vec<usize>,
    ) -> Option<Vec<usize>> {
        state[v] = 1;
        path.push(v);
        for &(a, b) in edges.iter().filter(|(a, _)| *a == v) {
            debug_assert_eq!(a, v);
            if state[b] == 1 {
                let start = path.iter().position(|&p| p == b).unwrap_or(0);
                let mut cyc = path[start..].to_vec();
                cyc.push(b);
                return Some(cyc);
            }
            if state[b] == 0 {
                if let Some(c) = dfs(b, edges, state, path) {
                    return Some(c);
                }
            }
        }
        path.pop();
        state[v] = 2;
        None
    }
    for v in 0..n {
        if state[v] == 0 {
            if let Some(c) = dfs(v, edges, &mut state, &mut path) {
                return Some(c);
            }
        }
    }
    None
}

/// Pass 1: index `fn` items with their `impl` owner.
fn index_functions(file: usize, toks: &[Token], out: &mut Vec<Func>) {
    let mut depth = 0usize;
    let mut impls: Vec<(Option<String>, usize)> = Vec::new(); // (owner, open depth)
    let mut i = 0usize;
    while i < toks.len() {
        match &toks[i].tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth = depth.saturating_sub(1);
                if let Some((_, d)) = impls.last() {
                    if depth < *d {
                        impls.pop();
                    }
                }
            }
            Tok::Ident(s) if s == "impl" => {
                // Owner = ident after `for` if present, else the first
                // ident outside generics.
                let mut j = i + 1;
                let mut angle = 0i32;
                let mut first: Option<String> = None;
                let mut after_for: Option<String> = None;
                let mut saw_for = false;
                while j < toks.len() && !toks[j].is_punct('{') && !toks[j].is_punct(';') {
                    match &toks[j].tok {
                        Tok::Punct('<') => angle += 1,
                        Tok::Punct('>') => angle -= 1,
                        Tok::Ident(id) if angle == 0 => {
                            if id == "for" {
                                saw_for = true;
                            } else if saw_for && after_for.is_none() {
                                after_for = Some(id.clone());
                            } else if first.is_none() {
                                first = Some(id.clone());
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                if j < toks.len() && toks[j].is_punct('{') {
                    impls.push((after_for.or(first), depth + 1));
                    depth += 1;
                    i = j + 1;
                    continue;
                }
                i = j;
            }
            Tok::Ident(s) if s == "fn" => {
                if let Some(Tok::Ident(name)) = toks.get(i + 1).map(|t| &t.tok) {
                    let name = name.clone();
                    // Find the body `{` (or `;` for bodyless items) at
                    // zero paren/bracket depth after the signature.
                    let mut j = i + 2;
                    let mut pd = 0i32;
                    while j < toks.len() {
                        match toks[j].tok {
                            Tok::Punct('(') | Tok::Punct('[') => pd += 1,
                            Tok::Punct(')') | Tok::Punct(']') => pd -= 1,
                            Tok::Punct('{') if pd == 0 => break,
                            Tok::Punct(';') if pd == 0 => break,
                            _ => {}
                        }
                        j += 1;
                    }
                    if j < toks.len() && toks[j].is_punct('{') {
                        let body_start = j + 1;
                        let end = matching_brace(toks, j);
                        let owner =
                            impls.last().and_then(|(o, _)| o.clone());
                        out.push(Func {
                            owner,
                            name,
                            file,
                            body: body_start..end,
                        });
                        // Keep walking *into* the body so nested fns
                        // (and impls in odd places) are indexed too.
                        i += 2;
                        continue;
                    }
                    i = j;
                }
            }
            _ => {}
        }
        i += 1;
    }
}

/// Token index of the `}` matching the `{` at `open`.
fn matching_brace(toks: &[Token], open: usize) -> usize {
    let mut d = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        match t.tok {
            Tok::Punct('{') => d += 1,
            Tok::Punct('}') => {
                d -= 1;
                if d == 0 {
                    return j;
                }
            }
            _ => {}
        }
    }
    toks.len()
}

/// Pass 2: walk one function body, tracking held guards.
fn extract(
    path: &str,
    toks: &[Token],
    f: &Func,
    diags: &mut Vec<Diagnostic>,
) -> Effects {
    let mut eff = Effects::default();
    let mut held: Vec<HeldLock> = Vec::new();
    let mut depth = 0usize;
    let mut i = f.body.start;
    while i < f.body.end {
        let t = &toks[i];
        match &t.tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth = depth.saturating_sub(1);
                held.retain(|h| h.depth <= depth);
            }
            Tok::Punct(';') => {
                held.retain(|h| !(h.temp && h.depth == depth));
            }
            Tok::Ident(s) if s == "fn" => {
                // Skip nested fn bodies: they are indexed separately
                // and don't run as part of this function.
                if matches!(toks.get(i + 1).map(|t| &t.tok), Some(Tok::Ident(_))) {
                    let mut j = i + 2;
                    while j < f.body.end && !toks[j].is_punct('{') && !toks[j].is_punct(';') {
                        j += 1;
                    }
                    if j < f.body.end && toks[j].is_punct('{') {
                        i = matching_brace(toks, j) + 1;
                        continue;
                    }
                    i = j;
                }
            }
            Tok::Ident(s) if s == "drop" && is_punct_at(toks, i + 1, '(') => {
                if let Some(Tok::Ident(g)) = toks.get(i + 2).map(|t| &t.tok) {
                    if is_punct_at(toks, i + 3, ')') {
                        if let Some(pos) =
                            held.iter().rposition(|h| h.guard.as_deref() == Some(g))
                        {
                            held.remove(pos);
                        }
                    }
                }
            }
            Tok::Punct('.')
                if ident_at(toks, i + 1) == Some("lock")
                    && is_punct_at(toks, i + 2, '(')
                    && is_punct_at(toks, i + 3, ')') =>
            {
                if !t.in_test {
                    match receiver_ident(toks, f.body.start, i) {
                        Some(recv) => match class_of(path, &recv) {
                            Some(class) => {
                                let held_now: Vec<usize> =
                                    held.iter().map(|h| h.class).collect();
                                eff.acquires.push((class, t.line, held_now));
                                let (guard, temp) =
                                    guard_binding(toks, f.body.start, i);
                                held.push(HeldLock { class, guard, depth, temp });
                            }
                            None => diags.push(Diagnostic::new(
                                path,
                                t.line,
                                RULE,
                                format!(
                                    "`.lock()` on unranked receiver `{recv}`; add it \
                                     to the lock-rank table in analysis::lockorder"
                                ),
                            )),
                        },
                        None => diags.push(Diagnostic::new(
                            path,
                            t.line,
                            RULE,
                            "`.lock()` with unresolvable receiver".to_string(),
                        )),
                    }
                }
                i += 4;
                continue;
            }
            Tok::Ident(name)
                if is_punct_at(toks, i + 1, '(')
                    && !KEYWORDS.contains(&name.as_str())
                    && !held.is_empty()
                    && !t.in_test =>
            {
                // Method or free-function call while locks are held.
                // Macros (`name!(...)`) have a `!` before the paren and
                // don't land here.
                let held_now: Vec<usize> = held.iter().map(|h| h.class).collect();
                if is_punct_at_back(toks, i, '.') {
                    // `recv.name(...)`: resolve the receiver ident.
                    if let Some(recv) = receiver_ident(toks, f.body.start, i - 1) {
                        if recv == "self" {
                            if let Some(owner) = &f.owner {
                                eff.calls.push((
                                    CallKey::Method(owner.clone(), name.clone()),
                                    t.line,
                                    held_now,
                                ));
                            }
                        } else if let Some((_, ty)) =
                            RECEIVER_TYPES.iter().find(|(id, _)| *id == recv)
                        {
                            eff.calls.push((
                                CallKey::Method(ty.to_string(), name.clone()),
                                t.line,
                                held_now,
                            ));
                        }
                    }
                } else if !is_punct_at_back(toks, i, ':') {
                    eff.calls.push((CallKey::Free(f.file, name.clone()), t.line, held_now));
                }
            }
            _ => {}
        }
        i += 1;
    }
    eff
}

fn ident_at(toks: &[Token], i: usize) -> Option<&str> {
    toks.get(i).and_then(|t| t.ident())
}

fn is_punct_at(toks: &[Token], i: usize, c: char) -> bool {
    toks.get(i).is_some_and(|t| t.is_punct(c))
}

fn is_punct_at_back(toks: &[Token], i: usize, c: char) -> bool {
    i > 0 && toks[i - 1].is_punct(c)
}

/// Scan backwards from the `.` at `dot` for the receiver's field
/// ident, skipping adapter-method chains like `.as_ref().expect(..)`.
fn receiver_ident(toks: &[Token], lo: usize, dot: usize) -> Option<String> {
    let mut i = dot;
    while i > lo {
        i -= 1;
        match &toks[i].tok {
            Tok::Punct(')') => {
                // Walk back over the balanced group, then expect an
                // adapter method name before it.
                let mut d = 1i32;
                while i > lo && d != 0 {
                    i -= 1;
                    match toks[i].tok {
                        Tok::Punct(')') => d += 1,
                        Tok::Punct('(') => d -= 1,
                        _ => {}
                    }
                }
                if i > lo {
                    if let Some(name) = toks[i - 1].ident() {
                        if ADAPTERS.contains(&name) {
                            i -= 1; // consume the adapter name
                            continue;
                        }
                        return Some(name.to_string());
                    }
                }
                return None;
            }
            Tok::Punct('.') | Tok::Punct('?') => {}
            Tok::Ident(s) => {
                if ADAPTERS.contains(&s.as_str()) {
                    continue;
                }
                return Some(s.clone());
            }
            _ => return None,
        }
    }
    None
}

/// Find the `let` binding (if any) for the statement containing the
/// acquisition at token `at`. Returns (guard name, is_temporary).
fn guard_binding(toks: &[Token], lo: usize, at: usize) -> (Option<String>, bool) {
    // Back up to the statement start.
    let mut s = at;
    while s > lo {
        match toks[s - 1].tok {
            Tok::Punct(';') | Tok::Punct('{') | Tok::Punct('}') | Tok::Punct(',') => break,
            _ => s -= 1,
        }
    }
    // Forward: a `let` before the first `=` names the guard.
    let mut saw_let = false;
    let mut last_ident: Option<String> = None;
    for t in &toks[s..at] {
        match &t.tok {
            Tok::Ident(id) if id == "let" => saw_let = true,
            Tok::Punct('=') => {
                return if saw_let {
                    (last_ident, false)
                } else {
                    (None, true)
                };
            }
            Tok::Ident(id) if saw_let => {
                if !matches!(id.as_str(), "mut" | "Ok" | "Some" | "Err" | "if" | "while") {
                    last_ident = Some(id.clone());
                }
            }
            _ => {}
        }
    }
    (None, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn run(fixtures: &[(&str, &str)]) -> Vec<Diagnostic> {
        let files: Vec<(String, LexFile)> = fixtures
            .iter()
            .map(|(p, s)| (p.to_string(), lex(s)))
            .collect();
        check(&files)
    }

    #[test]
    fn ranks_strictly_increase_and_match_runtime_table() {
        for w in LOCK_CLASSES.windows(2) {
            assert!(w[0].1 < w[1].1, "{} vs {}", w[0].0, w[1].0);
        }
        assert_eq!(LOCK_CLASSES[0].1, rank::BATCHER_QUEUES);
        assert_eq!(LOCK_CLASSES[LOCK_CLASSES.len() - 1].1, rank::METRICS);
    }

    #[test]
    fn blessed_order_passes() {
        // plan (20) -> staging (30): the prefetcher's real pattern.
        let d = run(&[(
            "rust/src/coordinator/pipeline.rs",
            r#"
            impl Prefetcher {
                fn tick(&self) {
                    let mut plan = self.shared.plan.lock().unwrap();
                    let mut inner = self.inner.lock().unwrap();
                    inner.touch(&mut plan);
                }
            }
            "#,
        )]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn seeded_out_of_order_fires() {
        // staging (30) then plan (20): must fire. The runtime twin of
        // this fixture lives in util::sync tests.
        let d = run(&[(
            "rust/src/coordinator/pipeline.rs",
            r#"
            impl Prefetcher {
                fn bad(&self) {
                    let mut inner = self.inner.lock().unwrap();
                    let mut plan = self.shared.plan.lock().unwrap();
                    plan.touch(&mut inner);
                }
            }
            "#,
        )]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, RULE);
        assert!(d[0].msg.contains("pipeline.plan"), "{}", d[0].msg);
        assert!(d[0].msg.contains("pipeline.staging"), "{}", d[0].msg);
        assert_eq!(d[0].line, 5);
    }

    #[test]
    fn re_entrant_acquisition_fires() {
        let d = run(&[(
            "rust/src/coordinator/batcher.rs",
            r#"
            impl Batcher {
                fn bad(&self) {
                    let a = self.queues.lock().unwrap();
                    let b = self.queues.lock().unwrap();
                }
            }
            "#,
        )]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].msg.contains("re-entrant"), "{}", d[0].msg);
    }

    #[test]
    fn guard_scope_and_drop_release() {
        // Block scoping and explicit drop() both release the guard, so
        // the later acquisition has nothing held against it.
        let d = run(&[(
            "rust/src/coordinator/pipeline.rs",
            r#"
            impl StagingArea {
                fn scoped(&self) {
                    {
                        let mut inner = self.inner.lock().unwrap();
                        inner.x += 1;
                    }
                    let mut plan = self.plan.lock().unwrap();
                }
                fn dropped(&self) {
                    let mut inner = self.inner.lock().unwrap();
                    drop(inner);
                    let mut plan = self.plan.lock().unwrap();
                }
            }
            "#,
        )]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn statement_temporary_releases_at_semicolon() {
        // A `.lock().unwrap().field` temporary dies at the `;` — no
        // edge to the next acquisition.
        let d = run(&[(
            "rust/src/coordinator/metrics.rs",
            r#"
            impl Metrics {
                fn bump(&self) {
                    self.inner.lock().unwrap().hits += 1;
                    let g = self.inner.lock().unwrap();
                }
            }
            "#,
        )]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn interprocedural_self_call_fires() {
        // Holding staging, `self.refill()` locks plan: edge staging ->
        // plan must be found through the call.
        let d = run(&[(
            "rust/src/coordinator/pipeline.rs",
            r#"
            impl StagingArea {
                fn bad(&self) {
                    let mut inner = self.inner.lock().unwrap();
                    self.refill(&mut inner);
                }
                fn refill(&self, inner: &mut Inner) {
                    let mut plan = self.plan.lock().unwrap();
                }
            }
            "#,
        )]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].msg.contains("via"), "{}", d[0].msg);
        assert_eq!(d[0].line, 5);
    }

    #[test]
    fn cross_type_call_via_receiver_hint() {
        // Holding the transport link state (rank 50), a call into
        // StagingArea::admit — which locks staging (rank 30) — must be
        // caught through the receiver-ident hint table.
        let d = run(&[
            (
                "rust/src/coordinator/pipeline.rs",
                r#"
                impl StagingArea {
                    fn admit(&self) {
                        let g = self.inner.lock().unwrap();
                    }
                }
                "#,
            ),
            (
                "rust/src/coordinator/transport.rs",
                r#"
                fn deliver(staging: &StagingArea, state: &OrderedMutex<LinkState>) {
                    let st = state.lock().unwrap();
                    staging.admit();
                }
                "#,
            ),
        ]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(
            d[0].msg.contains("pipeline.staging") && d[0].msg.contains("transport.link"),
            "{}",
            d[0].msg
        );
    }

    #[test]
    fn adapter_chain_resolves_receiver() {
        // pool.rs submit(): `self.tx.as_ref().expect("...").lock()`
        // must classify as pool.sender, not fail as unresolvable.
        let d = run(&[(
            "rust/src/util/pool.rs",
            r#"
            impl ThreadPool {
                fn submit(&self, job: Job) {
                    self.tx.as_ref().expect("pool shut down").lock().unwrap().send(job).unwrap();
                }
            }
            "#,
        )]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn unranked_receiver_is_reported_in_scope_only() {
        let bad = run(&[(
            "rust/src/coordinator/pipeline.rs",
            "fn f(m: &Mutex<u32>) { let g = mystery.lock().unwrap(); }",
        )]);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].msg.contains("mystery"), "{}", bad[0].msg);
        // Same code outside the scoped files: silent.
        let ok = run(&[(
            "rust/src/compeft/format.rs",
            "fn f(m: &Mutex<u32>) { let g = mystery.lock().unwrap(); }",
        )]);
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn test_regions_are_exempt() {
        let d = run(&[(
            "rust/src/coordinator/pipeline.rs",
            r#"
            #[cfg(test)]
            mod tests {
                fn helper(s: &StagingArea) {
                    let mut inner = s.inner.lock().unwrap();
                    let mut plan = s.plan.lock().unwrap();
                }
            }
            "#,
        )]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn cycle_detector_finds_cycles() {
        let mut edges = std::collections::BTreeSet::new();
        edges.insert((0usize, 1usize));
        edges.insert((1, 2));
        assert!(find_cycle(&edges).is_none());
        edges.insert((2, 0));
        let cyc = find_cycle(&edges).expect("cycle");
        assert!(cyc.len() >= 3, "{cyc:?}");
    }
}
