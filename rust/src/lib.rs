//! ComPEFT — compression for communicating parameter-efficient updates.
//!
//! Reproduction of Yadav et al., "ComPEFT: Compression for Communicating
//! Parameter Efficient Updates via Sparsification and Quantization"
//! (2023) as a three-layer Rust + JAX + Pallas system. See README.md for
//! the build/test/bench quickstart and the layer map.

pub mod analysis;
pub mod baselines;
pub mod bench_support;
pub mod compeft;
pub mod coordinator;
pub mod eval;
pub mod merging;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod workload;
