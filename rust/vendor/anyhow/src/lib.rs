//! Offline subset of the `anyhow` API.
//!
//! The build container has no crate registry, so this in-repo crate
//! provides the slice of `anyhow` the workspace actually uses:
//! [`Error`], [`Result`], the [`Context`] extension trait for `Result`
//! and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror upstream where it matters to callers:
//! * `{}` displays the outermost message; `{:#}` displays the whole
//!   context chain as `outer: inner: root`.
//! * `?` converts any `std::error::Error + Send + Sync + 'static`.
//! * `Error` deliberately does NOT implement `std::error::Error`, so the
//!   blanket `From` impl stays coherent (same trick as upstream).

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error value.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut stack = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            stack.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        stack.into_iter()
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(s) = cur.source.as_deref() {
            cur = s;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(mut cur) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            loop {
                write!(f, "\n    {}", cur.msg)?;
                match cur.source.as_deref() {
                    Some(next) => cur = next,
                    None => break,
                }
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our representation.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            out = Some(Error { msg, source: out.map(Box::new) });
        }
        out.expect("at least one message")
    }
}

mod ext {
    /// Unifies "things convertible into [`crate::Error`]" across std
    /// errors and `Error` itself without breaking coherence.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);
        fn bad() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(bad().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.root_cause(), "gone");

        let o: Option<u8> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn context_chains_on_anyhow_results() {
        fn inner() -> Result<()> {
            bail!("root failure {}", 1);
        }
        let e = inner().context("mid").context("top").unwrap_err();
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["top", "mid", "root failure 1"]);
    }

    #[test]
    fn ensure_and_bail() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).is_err());
    }
}
