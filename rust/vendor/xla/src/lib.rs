//! Stub of the `xla-rs` PJRT API surface used by `runtime/`.
//!
//! The container has no XLA/PJRT native toolchain, so this crate keeps
//! the runtime layer *compiling* while reporting the backend as
//! unavailable at construction time: [`PjRtClient::cpu`] returns an
//! error, and everything downstream of it is unreachable. All
//! PJRT-dependent tests and benches already skip when `make artifacts`
//! has not produced HLO artifacts, so the stub never fails a test — it
//! only gates the execution paths cleanly.
//!
//! Swapping in a real `xla` crate (same names, same signatures) re-lights
//! the PJRT path without touching `runtime/` or `coordinator/`.

use std::fmt;

/// Error raised by every fallible entry point of the stub.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA/PJRT backend is unavailable in this build \
             (rust/vendor/xla is a stub; install the PJRT toolchain and \
             swap in the real xla crate to execute HLO artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to device buffers.
pub trait NativeType: Copy + Send + Sync + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// PJRT client handle. The stub cannot construct one.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, loadable executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side literal value.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Shape of an array literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        let msg = err.to_string();
        assert!(msg.contains("unavailable"), "{msg}");
    }
}
