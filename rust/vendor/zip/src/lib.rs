//! Offline subset of the `zip` crate: stored (uncompressed) ZIP
//! archives only.
//!
//! The workspace exchanges tensors with NumPy through `.npz` files —
//! ZIP containers whose entries are written with `ZIP_STORED` (both by
//! our writer and by `np.savez`). The build container has no crate
//! registry, so this in-repo crate implements exactly the API surface
//! `util::npz` uses: [`ZipArchive`] (read), [`ZipWriter`] (write), and
//! [`write::FileOptions`] with [`CompressionMethod::Stored`].
//!
//! Reader notes: entry metadata (sizes, CRC) comes from the central
//! directory, so archives that use data descriptors or zip64 *extra
//! fields* (NumPy writes one) still parse; deflated entries and true
//! zip64 sizes are rejected with a clear error.

use std::fmt;
use std::io::{self, Cursor, Read, Seek, SeekFrom, Write};

/// Error type for archive operations.
#[derive(Debug)]
pub struct ZipError(String);

impl ZipError {
    fn new(msg: impl Into<String>) -> ZipError {
        ZipError(msg.into())
    }
}

impl fmt::Display for ZipError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "zip: {}", self.0)
    }
}

impl std::error::Error for ZipError {}

impl From<io::Error> for ZipError {
    fn from(e: io::Error) -> ZipError {
        ZipError(format!("io: {e}"))
    }
}

type ZipResult<T> = Result<T, ZipError>;

/// Compression method of an entry. Only `Stored` is supported.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CompressionMethod {
    #[default]
    Stored,
}

/// Writer-side options, mirroring `zip::write::FileOptions`.
pub mod write {
    use super::CompressionMethod;

    /// Per-entry options. `Copy` so one value can configure many entries.
    #[derive(Clone, Copy, Debug, Default)]
    pub struct FileOptions {
        pub(crate) method: CompressionMethod,
    }

    impl FileOptions {
        pub fn compression_method(mut self, method: CompressionMethod) -> FileOptions {
            self.method = method;
            self
        }
    }
}

// -- CRC32 (IEEE, reflected) ------------------------------------------------

fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, slot) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *slot = c;
    }
    let mut c = 0xFFFFFFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFFFFFF
}

// -- reading ----------------------------------------------------------------

const LOCAL_SIG: u32 = 0x04034b50;
const CENTRAL_SIG: u32 = 0x02014b50;
const EOCD_SIG: u32 = 0x06054b50;

#[derive(Clone, Debug)]
struct EntryMeta {
    name: String,
    method: u16,
    crc: u32,
    comp_size: u64,
    uncomp_size: u64,
    local_offset: u64,
}

/// Read-only view of a ZIP archive.
pub struct ZipArchive<R> {
    reader: R,
    /// Total archive length, used to bound per-entry reads: a hostile
    /// central directory can declare sizes up to ~4 GiB, and buffers
    /// must never be allocated from a claim the file cannot back.
    stream_len: u64,
    entries: Vec<EntryMeta>,
}

fn rd_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes([b[at], b[at + 1]])
}

fn rd_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

impl<R: Read + Seek> ZipArchive<R> {
    /// Parse the central directory of an archive.
    pub fn new(mut reader: R) -> ZipResult<ZipArchive<R>> {
        let len = reader.seek(SeekFrom::End(0))?;
        // EOCD is 22 bytes + up to 65535 bytes of trailing comment.
        let tail_len = len.min(22 + 65535);
        reader.seek(SeekFrom::Start(len - tail_len))?;
        let mut tail = vec![0u8; tail_len as usize];
        reader.read_exact(&mut tail)?;
        let eocd_at = (0..tail.len().saturating_sub(21))
            .rev()
            .find(|&i| rd_u32(&tail, i) == EOCD_SIG)
            .ok_or_else(|| ZipError::new("end-of-central-directory not found"))?;
        let eocd = &tail[eocd_at..];
        let n_entries = rd_u16(eocd, 10) as usize;
        let cd_size = rd_u32(eocd, 12) as u64;
        let cd_offset = rd_u32(eocd, 16) as u64;
        if cd_offset == 0xFFFFFFFF || n_entries == 0xFFFF {
            return Err(ZipError::new("zip64 archives are not supported"));
        }

        reader.seek(SeekFrom::Start(cd_offset))?;
        let mut cd = vec![0u8; cd_size as usize];
        reader.read_exact(&mut cd)?;

        let mut entries = Vec::with_capacity(n_entries);
        let mut pos = 0usize;
        for _ in 0..n_entries {
            if pos + 46 > cd.len() || rd_u32(&cd, pos) != CENTRAL_SIG {
                return Err(ZipError::new("bad central directory entry"));
            }
            let method = rd_u16(&cd, pos + 10);
            let crc = rd_u32(&cd, pos + 16);
            let comp_size = rd_u32(&cd, pos + 20) as u64;
            let uncomp_size = rd_u32(&cd, pos + 24) as u64;
            let name_len = rd_u16(&cd, pos + 28) as usize;
            let extra_len = rd_u16(&cd, pos + 30) as usize;
            let comment_len = rd_u16(&cd, pos + 32) as usize;
            let local_offset = rd_u32(&cd, pos + 42) as u64;
            if comp_size == 0xFFFFFFFF || uncomp_size == 0xFFFFFFFF {
                return Err(ZipError::new("zip64 entry sizes are not supported"));
            }
            if pos + 46 + name_len > cd.len() {
                return Err(ZipError::new("truncated central directory name"));
            }
            let name = String::from_utf8_lossy(&cd[pos + 46..pos + 46 + name_len])
                .into_owned();
            entries.push(EntryMeta {
                name,
                method,
                crc,
                comp_size,
                uncomp_size,
                local_offset,
            });
            pos += 46 + name_len + extra_len + comment_len;
        }
        Ok(ZipArchive { reader, stream_len: len, entries })
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Read the `i`-th entry (central-directory order) into memory.
    pub fn by_index(&mut self, i: usize) -> ZipResult<ZipFile> {
        let meta = self
            .entries
            .get(i)
            .cloned()
            .ok_or_else(|| ZipError::new(format!("entry index {i} out of range")))?;
        if meta.method != 0 {
            return Err(ZipError::new(format!(
                "entry {:?} uses compression method {} (only stored is supported)",
                meta.name, meta.method
            )));
        }
        // Local header: 30 fixed bytes, then name + extra, then data. Use
        // the *local* name/extra lengths (NumPy adds a zip64 extra field
        // here that is absent from the central directory).
        self.reader.seek(SeekFrom::Start(meta.local_offset))?;
        let mut lh = [0u8; 30];
        self.reader.read_exact(&mut lh)?;
        if rd_u32(&lh, 0) != LOCAL_SIG {
            return Err(ZipError::new(format!("bad local header for {:?}", meta.name)));
        }
        let name_len = rd_u16(&lh, 26) as u64;
        let extra_len = rd_u16(&lh, 28) as u64;
        // Bound the declared size by what the file can actually hold
        // *before* sizing the buffer from it: a lying central directory
        // must produce an error, not a multi-gigabyte allocation.
        let data_start = meta.local_offset + 30 + name_len + extra_len;
        if data_start + meta.comp_size > self.stream_len {
            return Err(ZipError::new(format!(
                "entry {:?} claims {} bytes past end of archive",
                meta.name, meta.comp_size
            )));
        }
        self.reader.seek(SeekFrom::Current((name_len + extra_len) as i64))?;
        let mut data = vec![0u8; meta.comp_size as usize];
        self.reader.read_exact(&mut data)?;
        if crc32(&data) != meta.crc {
            return Err(ZipError::new(format!("crc mismatch in entry {:?}", meta.name)));
        }
        Ok(ZipFile {
            name: meta.name,
            size: meta.uncomp_size,
            data: Cursor::new(data),
        })
    }
}

/// One archive entry, fully buffered in memory.
pub struct ZipFile {
    name: String,
    size: u64,
    data: Cursor<Vec<u8>>,
}

impl ZipFile {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Uncompressed size in bytes.
    pub fn size(&self) -> u64 {
        self.size
    }
}

impl Read for ZipFile {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        self.data.read(buf)
    }
}

// -- writing ----------------------------------------------------------------

struct DirRecord {
    name: String,
    crc: u32,
    size: u64,
    offset: u64,
}

/// Streaming ZIP writer (stored entries only).
pub struct ZipWriter<W: Write> {
    inner: W,
    offset: u64,
    current: Option<(String, Vec<u8>)>,
    records: Vec<DirRecord>,
}

impl<W: Write> ZipWriter<W> {
    pub fn new(inner: W) -> ZipWriter<W> {
        ZipWriter { inner, offset: 0, current: None, records: Vec::new() }
    }

    /// Begin a new entry; subsequent [`Write`] calls append to it.
    pub fn start_file<S: Into<String>>(
        &mut self,
        name: S,
        _options: write::FileOptions,
    ) -> ZipResult<()> {
        self.flush_entry()?;
        self.current = Some((name.into(), Vec::new()));
        Ok(())
    }

    fn put(&mut self, bytes: &[u8]) -> ZipResult<()> {
        self.inner.write_all(bytes)?;
        self.offset += bytes.len() as u64;
        Ok(())
    }

    fn flush_entry(&mut self) -> ZipResult<()> {
        let Some((name, data)) = self.current.take() else {
            return Ok(());
        };
        if data.len() as u64 > 0xFFFFFFFE || name.len() > 0xFFFF {
            return Err(ZipError::new("entry too large for non-zip64 archive"));
        }
        let crc = crc32(&data);
        let offset = self.offset;
        let mut header = Vec::with_capacity(30 + name.len());
        header.extend_from_slice(&LOCAL_SIG.to_le_bytes());
        header.extend_from_slice(&20u16.to_le_bytes()); // version needed
        header.extend_from_slice(&0u16.to_le_bytes()); // flags
        header.extend_from_slice(&0u16.to_le_bytes()); // method: stored
        header.extend_from_slice(&0u16.to_le_bytes()); // mtime
        header.extend_from_slice(&0u16.to_le_bytes()); // mdate
        header.extend_from_slice(&crc.to_le_bytes());
        header.extend_from_slice(&(data.len() as u32).to_le_bytes()); // comp
        header.extend_from_slice(&(data.len() as u32).to_le_bytes()); // uncomp
        header.extend_from_slice(&(name.len() as u16).to_le_bytes());
        header.extend_from_slice(&0u16.to_le_bytes()); // extra len
        header.extend_from_slice(name.as_bytes());
        self.put(&header)?;
        self.put(&data)?;
        self.records.push(DirRecord { name, crc, size: data.len() as u64, offset });
        Ok(())
    }

    /// Flush the last entry, write the central directory, and return the
    /// underlying writer.
    pub fn finish(mut self) -> ZipResult<W> {
        self.flush_entry()?;
        let cd_start = self.offset;
        let records = std::mem::take(&mut self.records);
        for rec in &records {
            if rec.offset > 0xFFFFFFFE {
                return Err(ZipError::new("archive too large for non-zip64"));
            }
            let mut h = Vec::with_capacity(46 + rec.name.len());
            h.extend_from_slice(&CENTRAL_SIG.to_le_bytes());
            h.extend_from_slice(&20u16.to_le_bytes()); // version made by
            h.extend_from_slice(&20u16.to_le_bytes()); // version needed
            h.extend_from_slice(&0u16.to_le_bytes()); // flags
            h.extend_from_slice(&0u16.to_le_bytes()); // method
            h.extend_from_slice(&0u16.to_le_bytes()); // mtime
            h.extend_from_slice(&0u16.to_le_bytes()); // mdate
            h.extend_from_slice(&rec.crc.to_le_bytes());
            h.extend_from_slice(&(rec.size as u32).to_le_bytes());
            h.extend_from_slice(&(rec.size as u32).to_le_bytes());
            h.extend_from_slice(&(rec.name.len() as u16).to_le_bytes());
            h.extend_from_slice(&0u16.to_le_bytes()); // extra
            h.extend_from_slice(&0u16.to_le_bytes()); // comment
            h.extend_from_slice(&0u16.to_le_bytes()); // disk
            h.extend_from_slice(&0u16.to_le_bytes()); // internal attrs
            h.extend_from_slice(&0u32.to_le_bytes()); // external attrs
            h.extend_from_slice(&(rec.offset as u32).to_le_bytes());
            h.extend_from_slice(rec.name.as_bytes());
            self.put(&h)?;
        }
        let cd_size = self.offset - cd_start;
        let mut eocd = Vec::with_capacity(22);
        eocd.extend_from_slice(&EOCD_SIG.to_le_bytes());
        eocd.extend_from_slice(&0u16.to_le_bytes()); // disk
        eocd.extend_from_slice(&0u16.to_le_bytes()); // cd disk
        eocd.extend_from_slice(&(records.len() as u16).to_le_bytes());
        eocd.extend_from_slice(&(records.len() as u16).to_le_bytes());
        eocd.extend_from_slice(&(cd_size as u32).to_le_bytes());
        eocd.extend_from_slice(&(cd_start as u32).to_le_bytes());
        eocd.extend_from_slice(&0u16.to_le_bytes()); // comment len
        self.put(&eocd)?;
        self.inner.flush()?;
        Ok(self.inner)
    }
}

impl<W: Write> Write for ZipWriter<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match &mut self.current {
            Some((_, data)) => {
                data.extend_from_slice(buf);
                Ok(buf.len())
            }
            None => Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "no entry open; call start_file first",
            )),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_archive(entries: &[(&str, &[u8])]) -> Vec<u8> {
        let mut w = ZipWriter::new(Cursor::new(Vec::new()));
        let opts = write::FileOptions::default()
            .compression_method(CompressionMethod::Stored);
        for (name, data) in entries {
            w.start_file(*name, opts).unwrap();
            w.write_all(data).unwrap();
        }
        w.finish().unwrap().into_inner()
    }

    #[test]
    fn roundtrip_multiple_entries() {
        let bytes =
            write_archive(&[("a.bin", b"hello"), ("dir/b.bin", &[0u8, 1, 2, 255])]);
        let mut ar = ZipArchive::new(Cursor::new(bytes)).unwrap();
        assert_eq!(ar.len(), 2);
        let mut f = ar.by_index(0).unwrap();
        assert_eq!(f.name(), "a.bin");
        assert_eq!(f.size(), 5);
        let mut buf = Vec::new();
        f.read_to_end(&mut buf).unwrap();
        assert_eq!(buf, b"hello");
        let mut f = ar.by_index(1).unwrap();
        assert_eq!(f.name(), "dir/b.bin");
        let mut buf = Vec::new();
        f.read_to_end(&mut buf).unwrap();
        assert_eq!(buf, vec![0u8, 1, 2, 255]);
    }

    #[test]
    fn empty_archive_roundtrip() {
        let bytes = write_archive(&[]);
        let ar = ZipArchive::new(Cursor::new(bytes)).unwrap();
        assert!(ar.is_empty());
    }

    #[test]
    fn corruption_is_detected() {
        let mut bytes = write_archive(&[("x", b"payload")]);
        // Flip a data byte: CRC check must fail.
        let at = bytes.iter().position(|&b| b == b'p').unwrap();
        bytes[at] ^= 0xFF;
        let mut ar = ZipArchive::new(Cursor::new(bytes)).unwrap();
        assert!(ar.by_index(0).is_err());
        assert!(ZipArchive::new(Cursor::new(b"garbage".to_vec())).is_err());
    }

    #[test]
    fn lying_comp_size_errs_before_allocating() {
        let mut bytes = write_archive(&[("x", b"payload")]);
        // Patch the central directory's compressed size to ~4 GiB. The
        // reader must reject it against the real archive length instead
        // of allocating (and zeroing) a 4 GiB buffer first.
        let cd = bytes
            .windows(4)
            .position(|w| w == CENTRAL_SIG.to_le_bytes())
            .unwrap();
        bytes[cd + 20..cd + 24].copy_from_slice(&0xFFFF_FFFEu32.to_le_bytes());
        let mut ar = ZipArchive::new(Cursor::new(bytes)).unwrap();
        let err = ar.by_index(0).unwrap_err();
        assert!(err.to_string().contains("past end of archive"), "{err}");
    }

    /// Bytes of `np.savez(buf, w=..., ids=...)` produced by NumPy 1.x —
    /// guards compatibility with the Python side's exporter (NumPy adds
    /// a zip64 extra field to local headers, which we must skip).
    const NUMPY_NPZ: &[u8] = &[
        80, 75, 3, 4, 20, 0, 0, 0, 0, 0, 0, 0, 33, 0, 78, 251,
        32, 117, 144, 0, 0, 0, 144, 0, 0, 0, 5, 0, 20, 0, 119, 46,
        110, 112, 121, 1, 0, 16, 0, 144, 0, 0, 0, 0, 0, 0, 0, 144,
        0, 0, 0, 0, 0, 0, 0, 147, 78, 85, 77, 80, 89, 1, 0, 118,
        0, 123, 39, 100, 101, 115, 99, 114, 39, 58, 32, 39, 60, 102, 52, 39,
        44, 32, 39, 102, 111, 114, 116, 114, 97, 110, 95, 111, 114, 100, 101, 114,
        39, 58, 32, 70, 97, 108, 115, 101, 44, 32, 39, 115, 104, 97, 112, 101,
        39, 58, 32, 40, 50, 44, 32, 50, 41, 44, 32, 125, 32, 32, 32, 32,
        32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32,
        32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32,
        32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32,
        32, 32, 32, 32, 32, 32, 10, 0, 0, 128, 63, 0, 0, 0, 64, 0,
        0, 64, 64, 0, 0, 128, 64, 80, 75, 3, 4, 20, 0, 0, 0, 0,
        0, 0, 0, 33, 0, 8, 101, 27, 91, 152, 0, 0, 0, 152, 0, 0,
        0, 7, 0, 20, 0, 105, 100, 115, 46, 110, 112, 121, 1, 0, 16, 0,
        152, 0, 0, 0, 0, 0, 0, 0, 152, 0, 0, 0, 0, 0, 0, 0,
        147, 78, 85, 77, 80, 89, 1, 0, 118, 0, 123, 39, 100, 101, 115, 99,
        114, 39, 58, 32, 39, 60, 105, 56, 39, 44, 32, 39, 102, 111, 114, 116,
        114, 97, 110, 95, 111, 114, 100, 101, 114, 39, 58, 32, 70, 97, 108, 115,
        101, 44, 32, 39, 115, 104, 97, 112, 101, 39, 58, 32, 40, 51, 44, 41,
        44, 32, 125, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32,
        32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32,
        32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32,
        32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 32, 10,
        7, 0, 0, 0, 0, 0, 0, 0, 8, 0, 0, 0, 0, 0, 0, 0,
        9, 0, 0, 0, 0, 0, 0, 0, 80, 75, 1, 2, 20, 3, 20, 0,
        0, 0, 0, 0, 0, 0, 33, 0, 78, 251, 32, 117, 144, 0, 0, 0,
        144, 0, 0, 0, 5, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
        128, 1, 0, 0, 0, 0, 119, 46, 110, 112, 121, 80, 75, 1, 2, 20,
        3, 20, 0, 0, 0, 0, 0, 0, 0, 33, 0, 8, 101, 27, 91, 152,
        0, 0, 0, 152, 0, 0, 0, 7, 0, 0, 0, 0, 0, 0, 0, 0,
        0, 0, 0, 128, 1, 199, 0, 0, 0, 105, 100, 115, 46, 110, 112, 121,
        80, 75, 5, 6, 0, 0, 0, 0, 2, 0, 2, 0, 104, 0, 0, 0,
        152, 1, 0, 0, 0, 0,
    ];

    #[test]
    fn reads_numpy_written_npz() {
        let mut ar = ZipArchive::new(Cursor::new(NUMPY_NPZ.to_vec())).unwrap();
        assert_eq!(ar.len(), 2);
        let mut names = Vec::new();
        for i in 0..ar.len() {
            let mut f = ar.by_index(i).unwrap();
            names.push(f.name().to_string());
            let mut buf = Vec::new();
            f.read_to_end(&mut buf).unwrap();
            assert_eq!(buf.len() as u64, f.size());
            assert_eq!(&buf[..6], b"\x93NUMPY");
        }
        names.sort();
        assert_eq!(names, vec!["ids.npy".to_string(), "w.npy".to_string()]);
    }
}
