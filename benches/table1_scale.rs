//! Table 1 + Table 2 + Figure 2: original vs ComPEFT accuracy on the
//! synthetic-MMLU benchmark across the µT scale ladder, with storage
//! sizes (Golomb-coded) and compression factors.
//!
//! Protocol mirrors §3.1: per expert, sweep (k, α) on the *validation*
//! split of the benchmark, pick the best point, report accuracy on the
//! *test* split plus the Golomb-coded size. Figure 2's two series
//! (MMLU improvement over base, compression factor) are emitted at the
//! end. Table 2 (the paper's LLaMA2-70B check) is the same protocol on
//! our largest scale restricted to 5 tasks.
//!
//! Run: `cargo bench --bench table1_scale`

use compeft::bench_support as bs;
use compeft::compeft::entropy::human_bytes;
use compeft::coordinator::registry::ExpertMethod;
use compeft::util::bench::Bench;

const INSTRUCT: [&str; 8] = [
    "self-instruct",
    "longform",
    "chip2",
    "hh-rlhf",
    "unnatural",
    "guanaco",
    "alpaca",
    "flan-v2",
];

fn main() -> anyhow::Result<()> {
    let artifacts = bs::require_artifacts();
    let mut bench = Bench::new("table1");
    let scales: Vec<String> = std::env::var("COMPEFT_SCALES")
        .unwrap_or_else(|_| "xs,s,m,l".into())
        .split(',')
        .map(|s| s.to_string())
        .collect();

    let test = bs::load_eval(&artifacts, "heldout_bench")?;
    let val = bs::load_eval(&artifacts, "heldout_bench_val")?.truncate(320);

    let mut fig2 = Vec::new();
    for scale in &scales {
        if !artifacts.join("models").join(scale).join("base.npz").exists() {
            eprintln!("scale {scale}: artifacts missing, skipping");
            continue;
        }
        let (_rt, bundle) = bs::load_bundle(&artifacts, scale)?;
        // Base model zero-shot reference (the paper's per-size base).
        let base_acc = compeft::eval::evaluate(
            &bundle,
            compeft::runtime::AdapterKind::Base,
            bs::EVAL_BATCH,
            None,
            None,
            &test,
        )?;
        bench.row(&format!("{scale}/base"), &[("mmlu_acc", base_acc * 100.0)]);

        let mut sum_orig = 0.0;
        let mut sum_comp = 0.0;
        let mut sum_ratio = 0.0;
        let mut n = 0.0;
        for task in INSTRUCT {
            let expert =
                match bs::load_expert(&artifacts, scale, task, "lora", None) {
                    Ok(e) => e,
                    Err(_) => continue,
                };
            let orig_acc = bs::eval_tv(&bundle, ExpertMethod::Lora, &expert.tv, &test)?;
            let grid =
                bs::sweep_cached(&bundle, &expert, &val, &format!("t1_{scale}_{task}"))?;
            let best = bs::best_point(&grid);
            let ctv = bs::compress_tv(&expert.tv, best.density, best.alpha);
            let comp_acc = bs::eval_tv(&bundle, ExpertMethod::Lora, &ctv, &test)?;
            let orig_bytes = expert.tv.bytes_fp16();
            let comp_bytes = bs::compeft_bytes(&expert.tv, best.density, best.alpha);
            let ratio = orig_bytes as f64 / comp_bytes as f64;
            bench.row(
                &format!("{scale}/{task}"),
                &[
                    ("orig_acc", orig_acc * 100.0),
                    ("compeft_acc", comp_acc * 100.0),
                    ("orig_kb", orig_bytes as f64 / 1e3),
                    ("compeft_kb", comp_bytes as f64 / 1e3),
                    ("ratio", ratio),
                    ("best_k", best.density),
                    ("best_alpha", best.alpha),
                ],
            );
            sum_orig += orig_acc;
            sum_comp += comp_acc;
            sum_ratio += ratio;
            n += 1.0;
        }
        if n > 0.0 {
            let avg_orig = sum_orig / n * 100.0;
            let avg_comp = sum_comp / n * 100.0;
            bench.row(
                &format!("{scale}/AVERAGE"),
                &[
                    ("orig_acc", avg_orig),
                    ("compeft_acc", avg_comp),
                    ("increase", avg_comp - avg_orig),
                    ("mean_ratio", sum_ratio / n),
                ],
            );
            fig2.push((scale.clone(), avg_comp - base_acc * 100.0, sum_ratio / n));
        }
    }

    println!("\n== Figure 2 series (improvement over base, compression factor) ==");
    for (scale, improve, ratio) in &fig2 {
        bench.row(
            &format!("fig2/{scale}"),
            &[("improvement_over_base", *improve), ("compression_x", *ratio)],
        );
    }

    // Table 2 analog: largest available scale, first 5 tasks — the grid
    // cache makes this a cheap re-slice of the same protocol.
    if let Some(scale) = scales.iter().rev().find(|s| {
        artifacts.join("models").join(s.as_str()).join("base.npz").exists()
    }) {
        println!("\n== Table 2 analog (scale {scale}, 5 tasks) ==");
        let (_rt, bundle) = bs::load_bundle(&artifacts, scale)?;
        for task in &INSTRUCT[..5] {
            if let Ok(expert) = bs::load_expert(&artifacts, scale, task, "lora", None) {
                let orig = bs::eval_tv(&bundle, ExpertMethod::Lora, &expert.tv, &test)?;
                let grid = bs::sweep_cached(
                    &bundle,
                    &expert,
                    &val,
                    &format!("t1_{scale}_{task}"),
                )?;
                let best = bs::best_point(&grid);
                let ctv = bs::compress_tv(&expert.tv, best.density, best.alpha);
                let comp = bs::eval_tv(&bundle, ExpertMethod::Lora, &ctv, &test)?;
                bench.row(
                    &format!("table2/{task}"),
                    &[
                        ("orig_acc", orig * 100.0),
                        ("compeft_acc", comp * 100.0),
                        ("delta", (comp - orig) * 100.0),
                    ],
                );
            }
        }
        let sample = bs::load_expert(&artifacts, scale, "alpaca", "lora", None)?;
        println!(
            "expert fp16 size at scale {scale}: {}",
            human_bytes(sample.tv.bytes_fp16())
        );
    }
    Ok(())
}
