//! Table 5: model transmission and loading latency — wall-clock time to
//! (a) download a checkpoint over the simulated internet link and
//! (b) move it host→device over the simulated PCIe link, for original
//! vs ComPEFT-compressed experts at every scale, 10 repetitions each
//! (mean ± std), exactly mirroring the paper's protocol with our link
//! models (DESIGN.md §3.5) over the real encoded bytes.
//!
//! Also measures the serving pipeline's prefetch-on vs prefetch-off
//! cold-swap stall on a synthetic mixed stored+composed workload —
//! artifact-free, so it runs in CI:
//!
//! Run: `cargo bench --bench table5_latency`
//!      `cargo bench --bench table5_latency -- --quick` (prefetch +
//!      decode rows only, no model artifacts; writes
//!      `BENCH_latency.json` unless `--json <path>` picks another
//!      artifact location)

use compeft::bench_support as bs;
use compeft::compeft::compress::CompressConfig;
use compeft::compeft::entropy::human_bytes;
use compeft::coordinator::archive::{build_from_registry, ArchiveTier};
use compeft::coordinator::cache::LruTier;
use compeft::coordinator::loader::ExpertLoader;
use compeft::coordinator::metrics::Metrics;
use compeft::coordinator::registry::{ExpertMethod, Registry};
use compeft::coordinator::store::{ExpertStore, StoreConfig};
use compeft::coordinator::transport::{LinkSpec, SimLink};
use compeft::coordinator::{PrepareContext, PreparedExpert, Prefetcher, TakeOutcome};
use compeft::merging::MergeMethod;
use compeft::tensor::{ParamSet, Tensor};
use compeft::util::bench::{json_flag, measure_peak, Bench, JsonSink, PeakAlloc};
use compeft::util::json::Json;
use compeft::util::pool::ThreadPool;
use compeft::util::rng::Pcg;
use compeft::util::stats;
use compeft::util::sync::{rank, OrderedMutex};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Measured (not modeled) peak heap for the zero-copy comparison rows.
#[global_allocator]
static ALLOC: PeakAlloc = PeakAlloc;

const REPS: usize = 10;

/// Mirror a printed row into the `--json` artifact (units inferred from
/// the field-name suffix convention the rows already follow).
fn sink_row(sink: &mut Option<JsonSink>, label: &str, fields: &[(&str, f64)]) {
    if let Some(s) = sink {
        for (k, v) in fields {
            let unit = if k.ends_with("_ms") {
                "ms"
            } else if k.ends_with("_us") {
                "us"
            } else if k.ends_with("_x") || k.contains("speedup") {
                "x"
            } else if *k == "bytes" {
                "bytes"
            } else {
                "count"
            };
            s.record(&format!("{label}/{k}"), *v, unit);
        }
    }
}

/// Prefetch-on vs prefetch-off: replay the same cold-swap sequence
/// (every step needs a fetch+decode; the workload cycles 4 stored
/// experts and a ternary-domain composition) through the actual
/// pipeline components at `time_scale = 0`. Off pays fetch+decode on
/// the "engine" thread each step; on overlaps them with the previous
/// step's (simulated) batch execution, paying only pickup + upload.
fn prefetch_comparison(
    bench: &mut Bench,
    sink: &mut Option<JsonSink>,
    quick: bool,
) -> anyhow::Result<()> {
    let elems: usize = if quick { 1 << 18 } else { 1 << 20 };
    let steps = 12usize;
    let depth = 2usize;

    let dir = std::env::temp_dir()
        .join(format!("compeft_t5_prefetch_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let mut reg = Registry::new();
    let ccfg = CompressConfig { density: 0.1, alpha: 1.0, ..Default::default() };
    let mut rng = Pcg::seed(2026);
    let mut shape_like = None;
    for i in 0..4 {
        let data: Vec<f32> =
            (0..elems).map(|_| rng.normal_ms(0.0, 7e-4) as f32).collect();
        let mut tv = ParamSet::new();
        tv.insert("w.lora_a", Tensor::new(vec![elems], data));
        let npz = dir.join(format!("e{i}.lora.npz"));
        tv.save_npz(&npz)?;
        reg.register_compeft(&format!("e{i}"), "t", "s", ExpertMethod::Lora, &npz, &ccfg)?;
        shape_like.get_or_insert(tv);
    }
    reg.register_composition(
        "merged",
        &["e0", "e1", "e2"],
        MergeMethod::Ties { density: 0.4, lambda: 1.0 },
    )?;
    let reg = Arc::new(reg);
    let templates = bs::zero_templates(&shape_like.unwrap());
    let targets = ["e0", "e1", "merged", "e2", "e3"];
    let workload: Vec<&str> = (0..steps).map(|i| targets[i % targets.len()]).collect();

    let mk_ctx = || -> Arc<PrepareContext> {
        Arc::new(PrepareContext {
            loader: ExpertLoader::new(
                SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
                SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
            )
            .with_pool(Arc::new(ThreadPool::new(4))),
            registry: Arc::clone(&reg),
            templates: templates.clone(),
            cpu: Arc::new(OrderedMutex::new(
                rank::CPU_TIER,
                "cache.cpu_tier",
                LruTier::new("cpu", 256 << 20),
            )),
            archive: None,
        })
    };

    // Calibrate the simulated batch-execution time to one blocking
    // fetch+decode, the regime where lookahead can hide a swap fully.
    let exec_time = {
        let ctx = mk_ctx();
        let t0 = Instant::now();
        let _ = ctx.prepare("e0")?;
        t0.elapsed()
    };

    // Prefetch off: every step pays the stages on the engine thread.
    let mut stall_off = Duration::ZERO;
    {
        let ctx = mk_ctx();
        for id in &workload {
            let t0 = Instant::now();
            let p: PreparedExpert = ctx.prepare(id)?;
            stall_off += t0.elapsed();
            std::mem::drop(p); // "upload + execute"
            std::thread::sleep(exec_time);
        }
    }

    // Prefetch on: stages overlap the previous step's execution.
    let mut stall_on = Duration::ZERO;
    let metrics = Arc::new(Metrics::new());
    {
        let ctx = mk_ctx();
        let pf = Prefetcher::start(Arc::clone(&ctx), depth, u64::MAX, Arc::clone(&metrics));
        for (i, id) in workload.iter().enumerate() {
            let t0 = Instant::now();
            let p = match pf.take(id) {
                TakeOutcome::Hit(p) | TakeOutcome::Waited(p, _) => p,
                TakeOutcome::Miss => ctx.prepare(id)?,
                TakeOutcome::Failed(e) => anyhow::bail!("prefetch failed: {e}"),
            };
            stall_on += t0.elapsed();
            std::mem::drop(p);
            let upcoming: Vec<String> =
                workload[i + 1..].iter().take(depth).map(|s| s.to_string()).collect();
            pf.note_plan(upcoming);
            std::thread::sleep(exec_time);
        }
    }
    let snap = metrics.snapshot();
    let fields = [
        ("elems", elems as f64),
        ("steps", steps as f64),
        ("exec_ms", exec_time.as_secs_f64() * 1e3),
        ("stall_off_ms", stall_off.as_secs_f64() * 1e3),
        ("stall_on_ms", stall_on.as_secs_f64() * 1e3),
        ("stall_hidden_x", stall_off.as_secs_f64() / stall_on.as_secs_f64().max(1e-9)),
        ("hits", snap.prefetch_hits as f64),
        ("waits", snap.prefetch_waits as f64),
        ("misses", snap.prefetch_misses as f64),
        ("overlap_saved_ms", snap.overlap_saved_us as f64 / 1e3),
    ];
    bench.row("prefetch/cold_swap_stall", &fields);
    sink_row(sink, "prefetch/cold_swap_stall", &fields);
    println!(
        "prefetch pipeline: engine-thread swap stall {:.1}ms -> {:.1}ms over {} cold \
         swaps ({} staged hits, {} waited, {} misses)",
        stall_off.as_secs_f64() * 1e3,
        stall_on.as_secs_f64() * 1e3,
        steps,
        snap.prefetch_hits,
        snap.prefetch_waits,
        snap.prefetch_misses,
    );
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// Fused vs staged cold swap through the sharded store: the same
/// multi-frame `.cpeft` expert (nnz well past the 8192-nonzero frame
/// size) run (a) staged — striped fetch, then decode — and (b) fused —
/// Golomb frames decode as their stripes land
/// (`ExpertLoader::fetch_decode_fused`). Both paths are asserted
/// bit-identical; the fused simulated cold-swap cost must come in
/// under the staged fetch+decode sum (≈ `max(fetch, decode)` instead
/// of the sum), and the hidden time shows up in `decode_overlap_us`.
fn fusion_comparison(
    bench: &mut Bench,
    sink: &mut Option<JsonSink>,
    quick: bool,
) -> anyhow::Result<()> {
    let elems: usize = if quick { 1 << 18 } else { 1 << 21 };
    let dir = std::env::temp_dir()
        .join(format!("compeft_t5_fusion_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let mut rng = Pcg::seed(909);
    let data: Vec<f32> = (0..elems).map(|_| rng.normal_ms(0.0, 7e-4) as f32).collect();
    let mut tv = ParamSet::new();
    tv.insert("w.lora_a", Tensor::new(vec![elems], data));
    let npz = dir.join("fused.lora.npz");
    tv.save_npz(&npz)?;
    let mut reg = Registry::new();
    let ccfg = CompressConfig { density: 0.1, alpha: 1.0, ..Default::default() };
    reg.register_compeft("fused", "t", "s", ExpertMethod::Lora, &npz, &ccfg)?;
    let rec = reg.get("fused").unwrap().clone();
    let template = ParamSet::load_npz(&npz)?;

    let metrics = Arc::new(Metrics::new());
    let mk_loader = || {
        // Fresh store per leg and per rep so link queueing never
        // accumulates across measurements (same discipline as the
        // striped-fetch rows).
        let mut cfg = StoreConfig::new(3, 2);
        cfg.time_scale = 0.0;
        cfg.stripe_bytes = 2048;
        let pool = Arc::new(ThreadPool::new(4));
        let store = Arc::new(ExpertStore::new(
            cfg,
            Some(Arc::clone(&pool)),
            Arc::clone(&metrics),
        ));
        ExpertLoader::new(
            SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
            SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
        )
        .with_pool(pool)
        .with_store(store)
    };

    // The fusion shape gate: the registry-built container must be a
    // single-part Golomb payload with several frames, or the bench is
    // not exercising what it claims to.
    {
        let (bytes, _) = mk_loader().fetch_encoded(&rec)?;
        let plan = compeft::compeft::format::golomb_frame_plan(bytes.as_slice())?
            .expect("registry-built .cpeft is a single-part Golomb container");
        assert!(
            plan.table.frames.len() > 1,
            "fusion bench needs a multi-frame payload ({} frame)",
            plan.table.frames.len()
        );
    }

    let mut staged_ms = Vec::with_capacity(REPS);
    let mut fused_ms = Vec::with_capacity(REPS);
    for _ in 0..REPS {
        let staged_loader = mk_loader();
        let (bytes, fetch) = staged_loader.fetch_encoded(&rec)?;
        let (tv_staged, decode) = staged_loader.decode(&rec, bytes.as_slice(), &template)?;
        staged_ms.push((fetch + decode).as_secs_f64() * 1e3);

        let fused = mk_loader()
            .fetch_decode_fused(&rec, &template)?
            .expect("store-backed .cpeft expert takes the fused path");
        assert_eq!(fused.tv, tv_staged, "fused decode must be bit-identical");
        assert!(
            fused.fused <= fused.fetch + fused.decode,
            "fused cold swap can never exceed its own staged sum"
        );
        fused_ms.push(fused.fused.as_secs_f64() * 1e3);
    }
    let staged_mean = stats::mean(&staged_ms);
    let fused_mean = stats::mean(&fused_ms);
    assert!(
        fused_mean < staged_mean,
        "fused cold swap ({fused_mean:.3} ms) must beat the staged \
         fetch+decode sum ({staged_mean:.3} ms)"
    );
    let snap = metrics.snapshot();
    assert_eq!(snap.fused_loads, REPS as u64, "every rep ran the fused path");
    let fields = [
        ("elems", elems as f64),
        ("encoded_bytes", rec.encoded_bytes as f64),
        ("staged_ms", staged_mean),
        ("fused_ms", fused_mean),
        ("hidden_ms", staged_mean - fused_mean),
        ("overlap_saved_ms", snap.decode_overlap_us as f64 / 1e3 / REPS as f64),
        ("speedup_x", staged_mean / fused_mean.max(1e-9)),
    ];
    bench.row("fusion/cold_swap_overlap", &fields);
    sink_row(sink, "fusion/cold_swap_overlap", &fields);
    println!(
        "fused fetch→decode: staged {staged_mean:.3} ms -> fused {fused_mean:.3} ms \
         per cold swap ({} of encoded payload, {:.3} ms decode overlap hidden/rep)",
        human_bytes(rec.encoded_bytes),
        snap.decode_overlap_us as f64 / 1e3 / REPS as f64,
    );
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// Striped-vs-single-link fetch of a multi-MB expert through the
/// sharded store (artifact-free): the same payload fetched from a
/// 1-node store (the flat link's exact cost) and from R-replica stores
/// whose stripes pull concurrently from R links. The fault-free run
/// must show zero retries/failovers, and multi-replica fetch must beat
/// the single link's wall time — the store's whole reason to exist.
fn striped_fetch_comparison(
    bench: &mut Bench,
    sink: &mut Option<JsonSink>,
    quick: bool,
) -> anyhow::Result<()> {
    let elems: usize = if quick { 1 << 20 } else { 1 << 22 };
    let dir = std::env::temp_dir()
        .join(format!("compeft_t5_striped_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    // A multi-MB expert: the dense fp16-accounted original (a 1M-param
    // LoRA is ~2 MB encoded; --quick keeps CI fast, the full run uses
    // 8 MB).
    let mut rng = Pcg::seed(5151);
    let data: Vec<f32> = (0..elems).map(|_| rng.normal_ms(0.0, 7e-4) as f32).collect();
    let mut tv = ParamSet::new();
    tv.insert("w.lora_a", Tensor::new(vec![elems], data));
    let npz = dir.join("big.lora.npz");
    tv.save_npz(&npz)?;
    let mut reg = Registry::new();
    reg.register_original("big", "t", "s", ExpertMethod::Lora, &npz)?;
    let rec = reg.get("big").unwrap().clone();

    let fetch_time = |nodes: usize, replication: usize| -> anyhow::Result<(f64, u64, u64)> {
        let mut ms = Vec::with_capacity(REPS);
        let metrics = Arc::new(Metrics::new());
        let mut retries = 0u64;
        let mut failovers = 0u64;
        for _ in 0..REPS {
            // Fresh store per rep so link queueing does not accumulate.
            let mut cfg = StoreConfig::new(nodes, replication);
            cfg.time_scale = 0.0;
            let store = ExpertStore::new(
                cfg,
                Some(Arc::new(ThreadPool::new(replication.max(2)))),
                Arc::clone(&metrics),
            );
            let (bytes, sim) = store.fetch(&rec)?;
            assert_eq!(bytes.len() as u64, std::fs::metadata(&rec.path)?.len());
            ms.push(sim.as_secs_f64() * 1e3);
            let snap = metrics.snapshot();
            retries = snap.stripe_retries;
            failovers = snap.failovers;
        }
        Ok((stats::mean(&ms), retries, failovers))
    };

    let (single_ms, r1, f1) = fetch_time(1, 1)?;
    let mut rows = vec![("single_link_ms".to_string(), single_ms)];
    let mut best = single_ms;
    for replication in [2usize, 3] {
        let (ms, r, f) = fetch_time(replication, replication)?;
        assert_eq!(r, 0, "fault-free run must not retry");
        assert_eq!(f, 0, "fault-free run must not fail over");
        rows.push((format!("striped_r{replication}_ms"), ms));
        rows.push((format!("striped_r{replication}_speedup"), single_ms / ms));
        best = best.min(ms);
    }
    assert_eq!((r1, f1), (0, 0), "single-node run is fault-free too");
    assert!(
        best < single_ms,
        "striped fetch ({best:.3} ms) must beat the single link ({single_ms:.3} ms)"
    );
    rows.push(("bytes".to_string(), rec.encoded_bytes as f64));
    rows.push(("stripe_retries".to_string(), 0.0));
    rows.push(("failovers".to_string(), 0.0));
    let rows_ref: Vec<(&str, f64)> = rows.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    bench.row("store/striped_fetch", &rows_ref);
    sink_row(sink, "store/striped_fetch", &rows_ref);
    println!(
        "striped fetch: single link {single_ms:.2} ms -> best replicated {best:.2} ms \
         ({:.2}x) over {} of encoded payload, 0 retries / 0 failovers",
        single_ms / best,
        human_bytes(rec.encoded_bytes),
    );
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// Archive-resident views vs host copies on a cold-swap fetch sweep:
/// the same expert pool fetched (a) over the flat link — every fetch
/// materializes a fresh heap buffer — and (b) as zero-copy views of a
/// resident `.cpar` image. Measures wall time and *measured* peak heap
/// (PeakAlloc is this binary's global allocator), and enforces the
/// zero-copy acceptance bar: the view path performs **zero** heap
/// copies of encoded payload bytes and its serving peak is a small
/// fraction of the copy path's.
fn archive_view_comparison(
    bench: &mut Bench,
    sink: &mut Option<JsonSink>,
    quick: bool,
) -> anyhow::Result<()> {
    let elems: usize = if quick { 1 << 18 } else { 1 << 20 };
    let n_experts = 4usize;
    let dir = std::env::temp_dir()
        .join(format!("compeft_t5_archive_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let mut reg = Registry::new();
    let ccfg = CompressConfig { density: 0.1, alpha: 1.0, ..Default::default() };
    let mut rng = Pcg::seed(4242);
    for i in 0..n_experts {
        let data: Vec<f32> =
            (0..elems).map(|_| rng.normal_ms(0.0, 7e-4) as f32).collect();
        let mut tv = ParamSet::new();
        tv.insert("w.lora_a", Tensor::new(vec![elems], data));
        let npz = dir.join(format!("e{i}.lora.npz"));
        tv.save_npz(&npz)?;
        reg.register_compeft(&format!("e{i}"), "t", "s", ExpertMethod::Lora, &npz, &ccfg)?;
    }
    let archive_path = dir.join("experts.cpar");
    let (members, archive_bytes) = build_from_registry(&reg, &archive_path)?;
    assert_eq!(members, n_experts);
    let mut ids = reg.ids();
    ids.sort();
    let recs: Vec<_> = ids.iter().map(|id| reg.get(id).unwrap().clone()).collect();
    let encoded_total: u64 = recs.iter().map(|r| r.encoded_bytes).sum();

    // Host-copy leg: flat-link fetch, one fresh buffer per expert.
    let flat_metrics = Arc::new(Metrics::new());
    let flat = ExpertLoader::new(
        SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
        SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
    )
    .with_meter(flat_metrics.copy_meter());
    let mut copy_ms = Vec::with_capacity(REPS);
    let mut host_peak = 0u64;
    let mut reference = Vec::new();
    for _ in 0..REPS {
        let (fetched, secs, peak) = measure_peak(|| -> anyhow::Result<Vec<_>> {
            recs.iter().map(|r| Ok(flat.fetch_encoded(r)?.0)).collect()
        });
        copy_ms.push(secs * 1e3);
        host_peak = host_peak.max(peak);
        reference = fetched?;
    }
    let host_copies = flat_metrics.snapshot().payload_copies;
    assert_eq!(
        host_copies,
        (REPS * n_experts) as u64,
        "every flat fetch materializes exactly one buffer"
    );

    // Resident-view leg: the archive image is opened (and resident)
    // up front, like an OS page cache; *serving* then hands out views.
    let arc_metrics = Arc::new(Metrics::new());
    let tier = ArchiveTier::open(&archive_path, Arc::clone(&arc_metrics))?;
    let mut view_ms = Vec::with_capacity(REPS);
    let mut view_peak = 0u64;
    let mut views = Vec::new();
    for _ in 0..REPS {
        let (got, secs, peak) = measure_peak(|| {
            recs.iter()
                .map(|r| tier.get(&r.id).expect("archived"))
                .collect::<Vec<_>>()
        });
        view_ms.push(secs * 1e3);
        view_peak = view_peak.max(peak);
        views = got;
    }
    for (v, want) in views.iter().zip(&reference) {
        assert_eq!(v, want, "archive view must be bit-identical to the flat fetch");
    }
    let snap = arc_metrics.snapshot();
    assert_eq!(
        snap.payload_copies, 0,
        "archive-resident serving performs zero heap copies of encoded bytes"
    );
    assert_eq!(snap.archive_hits, (REPS * n_experts) as u64);
    assert!(
        view_peak < host_peak / 8,
        "resident-view serving peak ({view_peak} B) must be a small fraction of \
         the host-copy peak ({host_peak} B)"
    );

    let copy_mean = stats::mean(&copy_ms);
    let view_mean = stats::mean(&view_ms);
    let fields = [
        ("experts", n_experts as f64),
        ("encoded_bytes", encoded_total as f64),
        ("archive_bytes", archive_bytes as f64),
        ("host_copy_ms", copy_mean),
        ("resident_view_ms", view_mean),
        ("fetch_speedup_x", copy_mean / view_mean.max(1e-9)),
        ("host_peak_bytes", host_peak as f64),
        ("view_peak_bytes", view_peak as f64),
        ("host_payload_copies", host_copies as f64),
        ("view_payload_copies", snap.payload_copies as f64),
    ];
    bench.row("archive/resident_view_vs_host_copy", &fields);
    sink_row(sink, "archive/resident_view_vs_host_copy", &fields);
    println!(
        "archive tier: {} experts ({} encoded) served as views of a {} image — \
         peak heap {} -> {} per sweep, {} -> 0 payload copies",
        n_experts,
        human_bytes(encoded_total),
        human_bytes(archive_bytes),
        human_bytes(host_peak),
        human_bytes(view_peak),
        host_copies,
    );
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    // `--quick` is the CI smoke shape: always leave the machine-readable
    // artifact behind (`BENCH_latency.json` unless `--json` chose a path).
    let json_path = json_flag(&args).or_else(|| {
        quick.then(|| std::path::PathBuf::from("BENCH_latency.json"))
    });
    let mut sink = json_path.map(|path| {
        let mut config = Json::obj();
        config.set("quick", Json::Bool(quick));
        JsonSink::new(path, "table5_latency", config)
    });
    let mut bench = Bench::new("table5");
    prefetch_comparison(&mut bench, &mut sink, quick)?;
    striped_fetch_comparison(&mut bench, &mut sink, quick)?;
    fusion_comparison(&mut bench, &mut sink, quick)?;
    archive_view_comparison(&mut bench, &mut sink, quick)?;
    if let Some(s) = &sink {
        s.write()?;
    }
    if quick {
        return Ok(());
    }
    let artifacts = bs::require_artifacts();

    let mut largest_npz = None;
    for scale in ["xs", "s", "m", "l"] {
        let npz = artifacts
            .join("experts")
            .join(scale)
            .join("alpaca.lora.npz");
        if !npz.exists() {
            continue;
        }
        let mut reg = Registry::new();
        reg.register_original("orig", "alpaca", scale, ExpertMethod::Lora, &npz)?;
        reg.register_compeft(
            "comp",
            "alpaca",
            scale,
            ExpertMethod::Lora,
            &npz,
            &CompressConfig { density: 0.1, alpha: 1.0, ..Default::default() },
        )?;

        for (id, label) in [("orig", "original"), ("comp", "compeft")] {
            let rec = reg.get(id).unwrap().clone();
            // Fresh links per config so queueing does not leak across.
            let loader = ExpertLoader::new(
                SimLink::new("net", LinkSpec::internet()),
                SimLink::new("pcie", LinkSpec::pcie()),
            );
            let mut net_ms = Vec::with_capacity(REPS);
            let mut pcie_ms = Vec::with_capacity(REPS);
            for _ in 0..REPS {
                let (_, fetch) = loader.fetch_encoded(&rec)?;
                net_ms.push(fetch.as_secs_f64() * 1e3);
                pcie_ms.push(loader.upload_cost(&rec).as_secs_f64() * 1e3);
            }
            bench.row(
                &format!("{scale}/{label}"),
                &[
                    ("bytes", rec.encoded_bytes as f64),
                    ("internet_ms_mean", stats::mean(&net_ms)),
                    ("internet_ms_std", stats::std(&net_ms)),
                    ("cpu_gpu_ms_mean", stats::mean(&pcie_ms)),
                    ("cpu_gpu_ms_std", stats::std(&pcie_ms)),
                ],
            );
        }

        let o = reg.get("orig").unwrap().encoded_bytes;
        let c = reg.get("comp").unwrap().encoded_bytes;
        println!(
            "scale {scale}: original {} vs compeft {} ({:.1}x smaller)",
            human_bytes(o),
            human_bytes(c),
            o as f64 / c as f64
        );
        largest_npz = Some((scale, npz));
    }

    // Decode worker scaling: the host-side half of a swap-in (v2 frame
    // decode + dense materialization) on the largest expert present,
    // serial vs the pooled loader at growing worker counts. Transfer
    // time is excluded (time_scale 0) — this isolates exactly the part
    // PR 2 parallelized.
    if let Some((scale, npz)) = largest_npz {
        let template = ParamSet::load_npz(&npz)?;
        let mut reg = Registry::new();
        reg.register_compeft(
            "dec",
            "alpaca",
            scale,
            ExpertMethod::Lora,
            &npz,
            &CompressConfig { density: 0.1, alpha: 1.0, ..Default::default() },
        )?;
        let rec = reg.get("dec").unwrap().clone();
        let mk_links = || {
            ExpertLoader::new(
                SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
                SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
            )
        };
        let (bytes, _) = mk_links().fetch_encoded(&rec)?;
        let time_decode = |loader: &ExpertLoader| -> anyhow::Result<f64> {
            let mut ms = Vec::with_capacity(REPS);
            for _ in 0..REPS {
                let (_, decode) = loader.decode(&rec, &bytes, &template)?;
                ms.push(decode.as_secs_f64() * 1e3);
            }
            Ok(stats::mean(&ms))
        };
        let serial_ms = time_decode(&mk_links())?;
        let mut fields: Vec<(String, f64)> =
            vec![("serial_ms".to_string(), serial_ms)];
        for workers in [1usize, 2, 4, 8] {
            let loader = mk_links().with_pool(Arc::new(ThreadPool::new(workers)));
            let ms = time_decode(&loader)?;
            fields.push((format!("w{workers}_ms"), ms));
            fields.push((format!("w{workers}_speedup"), serial_ms / ms));
        }
        let fields_ref: Vec<(&str, f64)> =
            fields.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        bench.row(&format!("{scale}/decode_worker_scaling"), &fields_ref);
    }

    // Paper-scale extrapolation: apply the same link model to LLaMA-sized
    // checkpoints (the paper's Table 5 row labels) at our measured
    // compression ratio so absolute magnitudes can be compared.
    println!("\n== paper-scale extrapolation (same link model) ==");
    let ratios = [("7B", 0.3e9, 16.0), ("13B", 0.47e9, 20.0), ("33B", 0.91e9, 16.0), ("65B", 1.49e9, 26.0)];
    for (name, orig_bytes, ratio) in ratios {
        let net = LinkSpec::internet();
        let pcie = LinkSpec::pcie();
        let comp_bytes = orig_bytes / ratio;
        bench.row(
            &format!("extrapolated/{name}"),
            &[
                ("orig_internet_s", net.duration_for(orig_bytes as u64).as_secs_f64()),
                ("comp_internet_s", net.duration_for(comp_bytes as u64).as_secs_f64()),
                ("orig_cpu_gpu_ms", pcie.duration_for(orig_bytes as u64).as_secs_f64() * 1e3),
                ("comp_cpu_gpu_ms", pcie.duration_for(comp_bytes as u64).as_secs_f64() * 1e3),
            ],
        );
    }
    Ok(())
}
