//! Table 5: model transmission and loading latency — wall-clock time to
//! (a) download a checkpoint over the simulated internet link and
//! (b) move it host→device over the simulated PCIe link, for original
//! vs ComPEFT-compressed experts at every scale, 10 repetitions each
//! (mean ± std), exactly mirroring the paper's protocol with our link
//! models (DESIGN.md §3.5) over the real encoded bytes.
//!
//! Run: `cargo bench --bench table5_latency`

use compeft::bench_support as bs;
use compeft::compeft::compress::CompressConfig;
use compeft::compeft::entropy::human_bytes;
use compeft::coordinator::loader::ExpertLoader;
use compeft::coordinator::registry::{ExpertMethod, Registry};
use compeft::coordinator::transport::{LinkSpec, SimLink};
use compeft::tensor::ParamSet;
use compeft::util::bench::Bench;
use compeft::util::pool::ThreadPool;
use compeft::util::stats;
use std::sync::Arc;

const REPS: usize = 10;

fn main() -> anyhow::Result<()> {
    let artifacts = bs::require_artifacts();
    let mut bench = Bench::new("table5");

    let mut largest_npz = None;
    for scale in ["xs", "s", "m", "l"] {
        let npz = artifacts
            .join("experts")
            .join(scale)
            .join("alpaca.lora.npz");
        if !npz.exists() {
            continue;
        }
        let mut reg = Registry::new();
        reg.register_original("orig", "alpaca", scale, ExpertMethod::Lora, &npz)?;
        reg.register_compeft(
            "comp",
            "alpaca",
            scale,
            ExpertMethod::Lora,
            &npz,
            &CompressConfig { density: 0.1, alpha: 1.0, ..Default::default() },
        )?;

        for (id, label) in [("orig", "original"), ("comp", "compeft")] {
            let rec = reg.get(id).unwrap().clone();
            // Fresh links per config so queueing does not leak across.
            let loader = ExpertLoader::new(
                SimLink::new("net", LinkSpec::internet()),
                SimLink::new("pcie", LinkSpec::pcie()),
            );
            let mut net_ms = Vec::with_capacity(REPS);
            let mut pcie_ms = Vec::with_capacity(REPS);
            for _ in 0..REPS {
                let (_, fetch) = loader.fetch_encoded(&rec)?;
                net_ms.push(fetch.as_secs_f64() * 1e3);
                pcie_ms.push(loader.upload_cost(&rec).as_secs_f64() * 1e3);
            }
            bench.row(
                &format!("{scale}/{label}"),
                &[
                    ("bytes", rec.encoded_bytes as f64),
                    ("internet_ms_mean", stats::mean(&net_ms)),
                    ("internet_ms_std", stats::std(&net_ms)),
                    ("cpu_gpu_ms_mean", stats::mean(&pcie_ms)),
                    ("cpu_gpu_ms_std", stats::std(&pcie_ms)),
                ],
            );
        }

        let o = reg.get("orig").unwrap().encoded_bytes;
        let c = reg.get("comp").unwrap().encoded_bytes;
        println!(
            "scale {scale}: original {} vs compeft {} ({:.1}x smaller)",
            human_bytes(o),
            human_bytes(c),
            o as f64 / c as f64
        );
        largest_npz = Some((scale, npz));
    }

    // Decode worker scaling: the host-side half of a swap-in (v2 frame
    // decode + dense materialization) on the largest expert present,
    // serial vs the pooled loader at growing worker counts. Transfer
    // time is excluded (time_scale 0) — this isolates exactly the part
    // PR 2 parallelized.
    if let Some((scale, npz)) = largest_npz {
        let template = ParamSet::load_npz(&npz)?;
        let mut reg = Registry::new();
        reg.register_compeft(
            "dec",
            "alpaca",
            scale,
            ExpertMethod::Lora,
            &npz,
            &CompressConfig { density: 0.1, alpha: 1.0, ..Default::default() },
        )?;
        let rec = reg.get("dec").unwrap().clone();
        let mk_links = || {
            ExpertLoader::new(
                SimLink::new("net", LinkSpec::internet()).with_time_scale(0.0),
                SimLink::new("pcie", LinkSpec::pcie()).with_time_scale(0.0),
            )
        };
        let (bytes, _) = mk_links().fetch_encoded(&rec)?;
        let time_decode = |loader: &ExpertLoader| -> anyhow::Result<f64> {
            let mut ms = Vec::with_capacity(REPS);
            for _ in 0..REPS {
                let (_, decode) = loader.decode(&rec, &bytes, &template)?;
                ms.push(decode.as_secs_f64() * 1e3);
            }
            Ok(stats::mean(&ms))
        };
        let serial_ms = time_decode(&mk_links())?;
        let mut fields: Vec<(String, f64)> =
            vec![("serial_ms".to_string(), serial_ms)];
        for workers in [1usize, 2, 4, 8] {
            let loader = mk_links().with_pool(Arc::new(ThreadPool::new(workers)));
            let ms = time_decode(&loader)?;
            fields.push((format!("w{workers}_ms"), ms));
            fields.push((format!("w{workers}_speedup"), serial_ms / ms));
        }
        let fields_ref: Vec<(&str, f64)> =
            fields.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        bench.row(&format!("{scale}/decode_worker_scaling"), &fields_ref);
    }

    // Paper-scale extrapolation: apply the same link model to LLaMA-sized
    // checkpoints (the paper's Table 5 row labels) at our measured
    // compression ratio so absolute magnitudes can be compared.
    println!("\n== paper-scale extrapolation (same link model) ==");
    let ratios = [("7B", 0.3e9, 16.0), ("13B", 0.47e9, 20.0), ("33B", 0.91e9, 16.0), ("65B", 1.49e9, 26.0)];
    for (name, orig_bytes, ratio) in ratios {
        let net = LinkSpec::internet();
        let pcie = LinkSpec::pcie();
        let comp_bytes = orig_bytes / ratio;
        bench.row(
            &format!("extrapolated/{name}"),
            &[
                ("orig_internet_s", net.duration_for(orig_bytes as u64).as_secs_f64()),
                ("comp_internet_s", net.duration_for(comp_bytes as u64).as_secs_f64()),
                ("orig_cpu_gpu_ms", pcie.duration_for(orig_bytes as u64).as_secs_f64() * 1e3),
                ("comp_cpu_gpu_ms", pcie.duration_for(comp_bytes as u64).as_secs_f64() * 1e3),
            ],
        );
    }
    Ok(())
}
