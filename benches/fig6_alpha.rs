//! Figure 6 (§4.2): validation accuracy vs scaling value α for every
//! density level, per scale — showing the inverse α–k relationship and
//! the flattening of the curve at larger scales (why α = 1 suffices for
//! big models).
//!
//! Run: `cargo bench --bench fig6_alpha`

use compeft::bench_support as bs;
use compeft::util::bench::Bench;

fn main() -> anyhow::Result<()> {
    let artifacts = bs::require_artifacts();
    let mut bench = Bench::new("fig6");
    let scales: Vec<String> = std::env::var("COMPEFT_SCALES")
        .unwrap_or_else(|_| "s,m,l".into())
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let tasks = ["alpaca", "flan-v2", "chip2"];
    let val = bs::load_eval(&artifacts, "heldout_bench_val")?.truncate(320);

    for scale in &scales {
        if !artifacts.join("models").join(scale).join("base.npz").exists() {
            continue;
        }
        let (_rt, bundle) = bs::load_bundle(&artifacts, scale)?;
        // Average the grid across tasks, then emit one row per (k, α).
        let mut grid_sum =
            vec![0.0f64; bs::DENSITIES.len() * bs::ALPHAS.len()];
        let mut n_tasks = 0.0;
        for task in tasks {
            let expert = match bs::load_expert(&artifacts, scale, task, "lora", None) {
                Ok(e) => e,
                Err(_) => continue,
            };
            let grid =
                bs::sweep_cached(&bundle, &expert, &val, &format!("t1_{scale}_{task}"))?;
            for (i, p) in grid.iter().enumerate() {
                grid_sum[i] += p.val_acc;
            }
            n_tasks += 1.0;
        }
        if n_tasks == 0.0 {
            continue;
        }
        let mut idx = 0;
        let mut per_k_spread = Vec::new();
        for &k in &bs::DENSITIES {
            let mut best = (0.0f64, 0.0f64); // (acc, alpha)
            let mut lo = f64::INFINITY;
            for &alpha in &bs::ALPHAS {
                let acc = grid_sum[idx] / n_tasks * 100.0;
                bench.row(
                    &format!("{scale}/k{:02.0}/a{alpha}", k * 100.0),
                    &[("val_acc", acc)],
                );
                if acc > best.0 {
                    best = (acc, alpha);
                }
                lo = lo.min(acc);
                idx += 1;
            }
            per_k_spread.push((k, best.1, best.0 - lo));
            bench.row(
                &format!("{scale}/k{:02.0}/BEST", k * 100.0),
                &[("best_alpha", best.1), ("best_acc", best.0), ("spread", best.0 - lo)],
            );
        }
        // Paper observation 2: optimal α decreases as k grows.
        println!(
            "scale {scale}: best α per k = {:?}",
            per_k_spread.iter().map(|r| (r.0, r.1)).collect::<Vec<_>>()
        );
    }
    Ok(())
}
