//! Table 10 (Appendix C.3): compressed LoRA vs inherently-smaller
//! lower-rank LoRA — does ComPEFT beat just training a smaller adapter?
//! Ranks {default, r/2, r/4} on the instruct tasks at one scale.
//!
//! Run: `cargo bench --bench table10_rank`

use compeft::bench_support as bs;
use compeft::coordinator::registry::ExpertMethod;
use compeft::util::bench::Bench;

fn main() -> anyhow::Result<()> {
    let artifacts = bs::require_artifacts();
    let mut bench = Bench::new("table10");
    let scale = std::env::var("COMPEFT_SCALE").unwrap_or_else(|_| "m".into());
    let tasks = ["self-instruct", "longform", "chip2", "hh-rlhf", "unnatural"];

    if !artifacts.join("models").join(&scale).join("base.npz").exists() {
        return Ok(());
    }
    let (_rt, bundle) = bs::load_bundle(&artifacts, &scale)?;
    let test = bs::load_eval(&artifacts, "heldout_bench")?.truncate(640);
    let val = bs::load_eval(&artifacts, "heldout_bench_val")?.truncate(320);

    // rank=None is the scale's default rank; 4 and 2 are the Table-10
    // analog of the paper's 64/32/8 ladder.
    for rank in [None, Some(4usize), Some(2usize)] {
        let mut s_orig = 0.0;
        let mut s_comp = 0.0;
        let mut s_bytes = (0.0, 0.0);
        let mut n = 0.0;
        for task in tasks {
            let expert =
                match bs::load_expert(&artifacts, &scale, task, "lora", rank) {
                    Ok(e) => e,
                    Err(_) => continue,
                };
            // Rank variants need a matching runtime adapter shape; the
            // executables are exported for the default rank only, so
            // lower-rank adapters are evaluated through their dense
            // delta on the weight matrices — identical math, same
            // protocol. (x@A)@B has the same result as x@(A@B).
            let (orig, comp, comp_bytes) = if rank.is_none() {
                let orig = bs::eval_tv(&bundle, ExpertMethod::Lora, &expert.tv, &test)?;
                let grid = bs::sweep_cached(
                    &bundle,
                    &expert,
                    &val,
                    &format!("t1_{scale}_{task}"),
                )?;
                let best = bs::best_point(&grid);
                let ctv = bs::compress_tv(&expert.tv, best.density, best.alpha);
                let comp = bs::eval_tv(&bundle, ExpertMethod::Lora, &ctv, &test)?;
                (orig, comp, bs::compeft_bytes(&expert.tv, best.density, best.alpha))
            } else {
                // Quick fixed-(k,α) protocol for the rank ladder.
                let grid = bs::sweep(
                    &bundle,
                    &rank_expert_as_default(&bundle, &expert)?,
                    &val,
                    &[0.2],
                    &[1.0, 2.0, 4.0],
                )?;
                let best = bs::best_point(&grid);
                let proj = rank_expert_as_default(&bundle, &expert)?;
                let orig = bs::eval_tv(&bundle, ExpertMethod::Lora, &proj.tv, &test)?;
                let ctv = bs::compress_tv(&proj.tv, best.density, best.alpha);
                let comp = bs::eval_tv(&bundle, ExpertMethod::Lora, &ctv, &test)?;
                (orig, comp, bs::compeft_bytes(&expert.tv, best.density, best.alpha))
            };
            s_orig += orig;
            s_comp += comp;
            s_bytes.0 += expert.tv.bytes_fp16() as f64;
            s_bytes.1 += comp_bytes as f64;
            n += 1.0;
        }
        if n > 0.0 {
            let label = rank.map(|r| format!("r{r}")).unwrap_or_else(|| "rdefault".into());
            bench.row(
                &format!("{scale}/{label}"),
                &[
                    ("lora_acc", s_orig / n * 100.0),
                    ("comlora_acc", s_comp / n * 100.0),
                    ("lora_kb", s_bytes.0 / n / 1e3),
                    ("comlora_kb", s_bytes.1 / n / 1e3),
                ],
            );
        }
    }
    Ok(())
}

/// Project a low-rank LoRA tv onto the default-rank adapter layout by
/// zero-padding the rank dimension: (x@A')@B' ≡ (x@A)@B when the extra
/// rank columns/rows are zero.
fn rank_expert_as_default(
    bundle: &compeft::runtime::ModelBundle,
    expert: &bs::Expert,
) -> anyhow::Result<bs::Expert> {
    use compeft::tensor::{ParamSet, Tensor};
    let mut tv = ParamSet::new();
    for name in bundle.lora_init.names() {
        let target = bundle.lora_init.get(name).unwrap();
        let src = expert
            .tv
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing {name}"))?;
        let mut out = Tensor::zeros(target.shape.clone());
        if name.ends_with("lora_a") {
            // [d, r_small] -> [d, r_big]
            let (d, rs) = (src.shape[0], src.shape[1]);
            let rb = target.shape[1];
            for i in 0..d {
                out.data[i * rb..i * rb + rs]
                    .copy_from_slice(&src.data[i * rs..(i + 1) * rs]);
            }
        } else {
            // [r_small, d] -> [r_big, d]
            let (rs, d) = (src.shape[0], src.shape[1]);
            out.data[..rs * d].copy_from_slice(&src.data);
        }
        tv.insert(name, out);
    }
    Ok(bs::Expert { tv, ..expert.clone() })
}
