//! Figure 5 (§4.1): ablation of the method components across density —
//! ComPEFT (tuned α) vs STC (mean-magnitude scale) vs Pruned (no
//! quantization) vs the original checkpoint, on the synthetic-MMLU
//! benchmark, for every density k ∈ {5,10,20,30,50}%.
//!
//! Run: `cargo bench --bench fig5_ablation`

use compeft::baselines::{pruned, stc::stc_compress};
use compeft::bench_support as bs;
use compeft::coordinator::registry::ExpertMethod;
use compeft::tensor::{ParamSet, Tensor};
use compeft::util::bench::Bench;

fn from_flat(like: &ParamSet, flat: &[f32]) -> ParamSet {
    like.unflatten_like(flat).unwrap()
}

fn main() -> anyhow::Result<()> {
    let artifacts = bs::require_artifacts();
    let mut bench = Bench::new("fig5");
    let scales: Vec<String> = std::env::var("COMPEFT_SCALES")
        .unwrap_or_else(|_| "s,m,l".into())
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let tasks = ["alpaca", "flan-v2", "chip2"];

    let test = bs::load_eval(&artifacts, "heldout_bench")?.truncate(640);
    let val = bs::load_eval(&artifacts, "heldout_bench_val")?.truncate(320);

    for scale in &scales {
        if !artifacts.join("models").join(scale).join("base.npz").exists() {
            continue;
        }
        let (_rt, bundle) = bs::load_bundle(&artifacts, scale)?;
        for density in bs::DENSITIES {
            let mut acc = [0.0f64; 4]; // compeft, stc, pruned, original
            let mut n = 0.0;
            for task in tasks {
                let expert =
                    match bs::load_expert(&artifacts, scale, task, "lora", None) {
                        Ok(e) => e,
                        Err(_) => continue,
                    };
                let flat = expert.tv.flatten();

                // ComPEFT: tuned α at this density (validation argmax).
                let grid = bs::sweep_cached(
                    &bundle,
                    &expert,
                    &val,
                    &format!("t1_{scale}_{task}"),
                )?;
                let best_alpha = grid
                    .iter()
                    .filter(|p| (p.density - density).abs() < 1e-9)
                    .max_by(|a, b| a.val_acc.partial_cmp(&b.val_acc).unwrap())
                    .map(|p| p.alpha)
                    .unwrap_or(1.0);
                let ctv = bs::compress_tv(&expert.tv, density, best_alpha);
                acc[0] += bs::eval_tv(&bundle, ExpertMethod::Lora, &ctv, &test)?;

                // STC: mean-magnitude scale, no tuning.
                let stc_dense = stc_compress(&flat, density).to_dense();
                acc[1] += bs::eval_tv(
                    &bundle,
                    ExpertMethod::Lora,
                    &from_flat(&expert.tv, &stc_dense),
                    &test,
                )?;

                // Pruned: values kept, no quantization.
                let pr = pruned(&flat, density).to_dense();
                acc[2] += bs::eval_tv(
                    &bundle,
                    ExpertMethod::Lora,
                    &from_flat(&expert.tv, &pr),
                    &test,
                )?;

                // Original.
                acc[3] += bs::eval_tv(&bundle, ExpertMethod::Lora, &expert.tv, &test)?;
                n += 1.0;
            }
            if n > 0.0 {
                bench.row(
                    &format!("{scale}/k{:02.0}", density * 100.0),
                    &[
                        ("compeft", acc[0] / n * 100.0),
                        ("stc", acc[1] / n * 100.0),
                        ("pruned", acc[2] / n * 100.0),
                        ("original", acc[3] / n * 100.0),
                    ],
                );
            }
        }
    }
    let _ = Tensor::zeros(vec![1]);
    Ok(())
}
