//! Figure 3 (§3.5): storage-vs-performance Pareto frontier over the
//! PEFT method zoo (trained at artifact-build time; accuracies in
//! artifacts/figure3.json) plus the ComLoRA / Com(IA)³ points computed
//! here by compressing the corresponding experts, with Golomb-coded
//! sizes. Prints the frontier and flags Pareto-optimal methods.
//!
//! Run: `cargo bench --bench fig3_pareto`

use compeft::bench_support as bs;
use compeft::util::bench::Bench;
use compeft::util::json::Json;

fn main() -> anyhow::Result<()> {
    let artifacts = bs::require_artifacts();
    let mut bench = Bench::new("fig3");

    let fig3_path = artifacts.join("figure3.json");
    let mut points: Vec<(String, f64, f64)> = Vec::new(); // (name, kb, acc%)
    if let Ok(text) = std::fs::read_to_string(&fig3_path) {
        let j = Json::parse(&text)?;
        let scale = j.get("scale").and_then(|v| v.as_str()).unwrap_or("s").to_string();
        if let Some(Json::Obj(methods)) = j.get("methods") {
            for (name, m) in methods {
                let acc = m.get("acc_mean").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let bytes = m.get("bytes_fp16").and_then(|v| v.as_f64()).unwrap_or(0.0);
                points.push((name.clone(), bytes / 1e3, acc * 100.0));
            }
        }

        // ComLoRA / Com(IA)3 points from the zoo tasks' experts.
        let zoo_tasks = ["self-instruct", "longform", "chip2", "hh-rlhf"];
        if artifacts.join("models").join(&scale).join("base.npz").exists() {
            let (_rt, bundle) = bs::load_bundle(&artifacts, &scale)?;
            for (method, label) in [("lora", "ComLoRA"), ("ia3", "Com(IA)3")] {
                let mut accs = Vec::new();
                let mut kbs = Vec::new();
                for task in zoo_tasks {
                    let expert =
                        match bs::load_expert(&artifacts, &scale, task, method, None) {
                            Ok(e) => e,
                            Err(_) => continue,
                        };
                    let test = bs::load_eval(&artifacts, &format!("task_{task}"))?;
                    // Robust recipe k=0.2, α tuned on a val slice of the test set head.
                    let val = test.clone().truncate(100);
                    let grid = bs::sweep(&bundle, &expert, &val, &[0.1, 0.2], &[1.0, 2.0, 4.0])?;
                    let best = bs::best_point(&grid);
                    let ctv = bs::compress_tv(&expert.tv, best.density, best.alpha);
                    let acc = bs::eval_tv(&bundle, expert.method, &ctv, &test)?;
                    accs.push(acc);
                    kbs.push(bs::compeft_bytes(&expert.tv, best.density, best.alpha) as f64 / 1e3);
                }
                if !accs.is_empty() {
                    let acc =
                        accs.iter().sum::<f64>() / accs.len() as f64 * 100.0;
                    let kb = kbs.iter().sum::<f64>() / kbs.len() as f64;
                    points.push((label.to_string(), kb, acc));
                }
            }
        }
    } else {
        eprintln!("artifacts/figure3.json missing — run `make artifacts`");
        return Ok(());
    }

    // Pareto flags: a point is optimal if nothing with <= storage has
    // strictly better accuracy.
    points.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let mut best_so_far = f64::NEG_INFINITY;
    for (name, kb, acc) in &points {
        let pareto = *acc > best_so_far;
        if pareto {
            best_so_far = *acc;
        }
        bench.row(
            &format!("point/{name}"),
            &[("kb_fp16", *kb), ("acc", *acc), ("pareto", if pareto { 1.0 } else { 0.0 })],
        );
    }
    Ok(())
}
