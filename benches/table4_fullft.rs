//! Table 4: ComPEFT on *fully fine-tuned* residuals — the GLUE-analog
//! tasks fine-tuned with full-model training, compressed and evaluated
//! on their own test sets (BERT/RoBERTa/T5 analogs → µT xs/s).
//!
//! Run: `cargo bench --bench table4_fullft`

use compeft::bench_support as bs;
use compeft::util::bench::Bench;

const GLUE: [&str; 7] = ["mnli", "rte", "qnli", "wnli", "sst2", "mrpc", "qqp"];

fn main() -> anyhow::Result<()> {
    let artifacts = bs::require_artifacts();
    let mut bench = Bench::new("table4");

    for scale in ["xs", "s"] {
        if !artifacts.join("models").join(scale).join("base.npz").exists() {
            continue;
        }
        let (_rt, bundle) = bs::load_bundle(&artifacts, scale)?;
        let mut sums = (0.0, 0.0, 0.0, 0usize);
        for task in GLUE {
            let expert = match bs::load_expert(&artifacts, scale, task, "full", None) {
                Ok(e) => e,
                Err(_) => continue,
            };
            let test = bs::load_eval(&artifacts, &format!("glue_{task}"))?;
            let val = bs::load_eval(&artifacts, &format!("glue_{task}_val"))?.truncate(160);
            let orig = bs::eval_tv(&bundle, expert.method, &expert.tv, &test)?;
            let grid = bs::sweep_cached(
                &bundle,
                &expert,
                &val,
                &format!("t4_{scale}_{task}_full"),
            )?;
            let best = bs::best_point(&grid);
            let ctv = bs::compress_tv(&expert.tv, best.density, best.alpha);
            let comp = bs::eval_tv(&bundle, expert.method, &ctv, &test)?;
            let orig_b = expert.tv.bytes_fp16();
            let comp_b = bs::compeft_bytes(&expert.tv, best.density, best.alpha);
            bench.row(
                &format!("{scale}/full/{task}"),
                &[
                    ("orig_acc", orig * 100.0),
                    ("compeft_acc", comp * 100.0),
                    ("orig_mb", orig_b as f64 / 1e6),
                    ("compeft_mb", comp_b as f64 / 1e6),
                    ("ratio", orig_b as f64 / comp_b as f64),
                ],
            );
            sums = (
                sums.0 + orig,
                sums.1 + comp,
                sums.2 + orig_b as f64 / comp_b as f64,
                sums.3 + 1,
            );
        }
        if sums.3 > 0 {
            let n = sums.3 as f64;
            bench.row(
                &format!("{scale}/full/AVERAGE"),
                &[
                    ("orig_acc", sums.0 / n * 100.0),
                    ("compeft_acc", sums.1 / n * 100.0),
                    ("improvement", (sums.1 - sums.0) / n * 100.0),
                    ("mean_ratio", sums.2 / n),
                ],
            );
        }
    }
    Ok(())
}
