//! Table 7 (Appendix B.4/B.5): task-vector statistics — mean, std, max,
//! min per scale and task, verifying the near-zero-mean / small-σ
//! structure ComPEFT exploits and that σ shrinks as scale grows.
//!
//! Run: `cargo bench --bench table7_stats`

use compeft::bench_support as bs;
use compeft::util::bench::Bench;
use compeft::util::stats;

fn main() -> anyhow::Result<()> {
    let artifacts = bs::require_artifacts();
    let mut bench = Bench::new("table7");

    let mut sigma_by_scale: Vec<(String, f64)> = Vec::new();
    for scale in ["xs", "s", "m", "l"] {
        let mut sigmas = Vec::new();
        for task in ["chip2", "longform"] {
            let expert = match bs::load_expert(&artifacts, scale, task, "lora", None) {
                Ok(e) => e,
                Err(_) => continue,
            };
            let flat = expert.tv.flatten();
            let mean = stats::mean_f32(&flat);
            let sigma = stats::std_f32(&flat);
            let max = flat.iter().cloned().fold(f32::MIN, f32::max) as f64;
            let min = flat.iter().cloned().fold(f32::MAX, f32::min) as f64;
            bench.row(
                &format!("{scale}/{task}"),
                &[
                    ("tv_mean", mean),
                    ("tv_std", sigma),
                    ("tv_max", max),
                    ("tv_min", min),
                    ("mean_over_std", if sigma > 0.0 { mean / sigma } else { 0.0 }),
                ],
            );
            sigmas.push(sigma);
        }
        if !sigmas.is_empty() {
            sigma_by_scale.push((scale.to_string(), stats::mean(&sigmas)));
        }
    }

    // The paper's observation: σ varies strongly with model size, which
    // is why α·σ (not a fixed constant) is the right scale.
    println!("\nσ by scale: {sigma_by_scale:?}");
    if sigma_by_scale.len() >= 2 {
        let first = sigma_by_scale.first().unwrap().1;
        let last = sigma_by_scale.last().unwrap().1;
        println!(
            "σ({}) / σ({}) = {:.2}",
            sigma_by_scale.first().unwrap().0,
            sigma_by_scale.last().unwrap().0,
            first / last
        );
    }
    Ok(())
}
