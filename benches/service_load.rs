//! Trace-driven serving load harness: seeded multi-tenant traces
//! (steady Zipf, flash crowd, diurnal, bursty) replayed through the real
//! batcher + admission control on the deterministic sim clock
//! ([`compeft::workload::sim`]), reporting tail latency, goodput, shed
//! rate, and residency/prefetch counters per scenario — plus the
//! headline overload row showing deadline-aware shedding beating
//! admit-everything on goodput.
//!
//! Artifact-free: runs in CI.
//!
//! Run: `cargo bench --bench service_load`
//!      `cargo bench --bench service_load -- --quick` (shorter traces,
//!      steady/flash/diurnal + the overload, closed-loop, rebalance, and
//!      delta rows; writes `BENCH_service.json` even without `--json`)
//!      `... -- --json <path>` (machine-readable
//!      `{bench, row, value, unit, config}` records at a chosen path)
//!
//! Every number is a pure function of `(--seed, config)`: rerunning a
//! row — at any `COMPEFT_TEST_WORKERS`, on any machine — reproduces it
//! bit-for-bit.

use compeft::compeft::compress::{compress_params, CompressConfig, Granularity};
use compeft::compeft::engine::{apply_delta, compress_delta};
use compeft::compeft::format::{to_bytes, Encoding};
use compeft::coordinator::admission::AdmissionConfig;
use compeft::tensor::{ParamSet, Tensor};
use compeft::util::bench::{json_flag, Bench, JsonSink};
use compeft::util::json::Json;
use compeft::util::rng::Pcg;
use compeft::workload::sim::{self, Mode, ServiceModel, SimConfig, SimReport};
use compeft::workload::{Trace, TraceSpec};

/// Per-scenario shape shared by every row.
struct Shape {
    duration_us: u64,
    n_experts: u32,
    tenants: usize,
    total_rps: f64,
}

fn report_fields(trace: &Trace, r: &SimReport) -> Vec<(&'static str, f64, &'static str)> {
    vec![
        ("offered_rps", trace.offered_rps(), "rps"),
        ("submitted", r.submitted as f64, "count"),
        ("accepted", r.accepted as f64, "count"),
        ("completed", r.completed as f64, "count"),
        ("deadline_met", r.deadline_met as f64, "count"),
        ("goodput_rps", r.goodput_rps(), "rps"),
        ("shed_rate", r.shed_rate(), "frac"),
        ("shed_deadline", r.shed.shed_deadline as f64, "count"),
        ("shed_queue_full", r.shed.queue_full as f64, "count"),
        ("p50_us", r.p50_us(), "us"),
        ("p99_us", r.p99_us(), "us"),
        ("p999_us", r.p999_us(), "us"),
        ("mean_us", r.latency.mean_us(), "us"),
        ("batches", r.batches as f64, "count"),
        ("swaps", r.swaps as f64, "count"),
        ("fetches", r.fetches as f64, "count"),
        ("prefetch_hits", r.prefetch_hits as f64, "count"),
        ("max_queued", r.max_queued as f64, "count"),
    ]
}

fn emit(
    bench: &mut Bench,
    sink: &mut Option<JsonSink>,
    label: &str,
    fields: &[(&'static str, f64, &'static str)],
) {
    let plain: Vec<(&str, f64)> = fields.iter().map(|(k, v, _)| (*k, *v)).collect();
    bench.row(label, &plain);
    if let Some(s) = sink {
        s.record_row(label, fields);
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let seed = args
        .iter()
        .position(|a| a == "--seed")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(2026u64);
    let shape = if quick {
        Shape { duration_us: 1_000_000, n_experts: 32, tenants: 4, total_rps: 500.0 }
    } else {
        Shape { duration_us: 8_000_000, n_experts: 64, tenants: 4, total_rps: 1_500.0 }
    };

    let mut bench = Bench::new("service_load");
    // `--quick` (the CI leg) always leaves a machine-readable artifact
    // behind — CI uploads `BENCH_service.json` from both matrix legs
    // without having to thread a path through the workflow.
    let json_path = json_flag(&args).or_else(|| {
        if quick {
            Some(std::path::PathBuf::from("BENCH_service.json"))
        } else {
            None
        }
    });
    let mut sink = json_path.map(|path| {
        let mut config = Json::obj();
        config
            .set("seed", Json::num(seed as f64))
            .set("quick", Json::Bool(quick))
            .set("duration_us", Json::num(shape.duration_us as f64))
            .set("n_experts", Json::num(f64::from(shape.n_experts)))
            .set("tenants", Json::num(shape.tenants as f64))
            .set("total_rps", Json::num(shape.total_rps));
        JsonSink::new(path, "service_load", config)
    });

    // Standard serving configuration for the scenario rows: default
    // batcher + residency model, bounded queue, deadline shedding with a
    // mid-range per-batch estimate.
    let serving = SimConfig {
        admission: AdmissionConfig {
            queue_cap: 1_024,
            shed_deadline: true,
            est_batch_us: 20_000,
            ..Default::default()
        },
        ..Default::default()
    };

    let scenarios: &[&str] = if quick {
        &["steady", "flash", "diurnal"]
    } else {
        &["steady", "flash", "diurnal", "bursty"]
    };
    for name in scenarios {
        let spec = TraceSpec::scenario(
            name,
            shape.duration_us,
            shape.n_experts,
            shape.tenants,
            shape.total_rps,
        )
        .expect("catalog scenario");
        let trace = Trace::generate(&spec, seed);
        let r = sim::run(&trace, &serving);
        emit(&mut bench, &mut sink, name, &report_fields(&trace, &r));
    }

    // Headline overload row: the same 9×-saturated steady trace served
    // with admission control off vs deadline-aware shedding on. With
    // everything admitted, queueing delay blows every budget and goodput
    // collapses; shedding keeps admitted requests inside their
    // deadlines. The assert makes this a regression gate, not just a
    // table entry.
    {
        let mut spec = TraceSpec::steady_zipf(
            if quick { 2_000_000 } else { 4_000_000 },
            shape.n_experts,
            2,
            1_500.0,
        );
        for t in &mut spec.tenants {
            t.deadline_us = 100_000;
        }
        let trace = Trace::generate(&spec, seed);
        // One residency slot, no prefetch: every batch pays the full
        // cold-swap cost, saturating the server near 170 rps.
        let model = ServiceModel { gpu_slots: 1, prefetch_depth: 0, ..Default::default() };
        let off = sim::run(&trace, &SimConfig { model, ..Default::default() });
        let on = sim::run(
            &trace,
            &SimConfig {
                model,
                admission: AdmissionConfig {
                    shed_deadline: true,
                    est_batch_us: 46_000,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        assert!(
            on.goodput_rps() > off.goodput_rps(),
            "deadline-aware shedding must beat no-shedding on goodput under overload \
             ({:.1} rps vs {:.1} rps)",
            on.goodput_rps(),
            off.goodput_rps()
        );
        let mut fields = report_fields(&trace, &on);
        fields.push(("goodput_rps_no_shed", off.goodput_rps(), "rps"));
        fields.push(("p999_us_no_shed", off.p999_us(), "us"));
        fields.push(("goodput_gain_x", on.goodput_rps() / off.goodput_rps().max(1e-9), "x"));
        emit(&mut bench, &mut sink, "overload_shed_vs_admit_all", &fields);
    }

    // Closed-loop throughput probe: 64 outstanding requests, arrival
    // timestamps ignored — measures sustainable service rate rather
    // than behavior at a fixed offered load.
    {
        let spec = TraceSpec::steady_zipf(
            shape.duration_us,
            shape.n_experts,
            shape.tenants,
            shape.total_rps,
        );
        let trace = Trace::generate(&spec, seed);
        let r = sim::run(
            &trace,
            &SimConfig { mode: Mode::Closed { concurrency: 64 }, ..Default::default() },
        );
        let throughput = r.completed as f64 / (r.duration_us as f64 / 1e6).max(1e-9);
        let mut fields = report_fields(&trace, &r);
        fields.push(("throughput_rps", throughput, "rps"));
        emit(&mut bench, &mut sink, "closed_loop_c64", &fields);
    }

    // Adaptive-replication row: the same Zipf trace served with fixed
    // base replication vs the popularity-driven rebalancer widening the
    // hot tail. Widened experts stripe their fetches across more store
    // nodes, so the tail of the latency distribution must not get worse
    // — asserted, so a controller regression fails the bench.
    {
        let spec = TraceSpec::steady_zipf(
            if quick { 2_000_000 } else { 4_000_000 },
            shape.n_experts,
            2,
            600.0,
        );
        let trace = Trace::generate(&spec, seed);
        // Tight residency, no prefetch: the Zipf head refetches
        // constantly, so fetch time dominates the tail and adaptive
        // replication has something to optimize.
        let fixed_model = ServiceModel {
            gpu_slots: 2,
            prefetch_depth: 0,
            store_nodes: 4,
            replication: 1,
            ..Default::default()
        };
        let fixed = sim::run(&trace, &SimConfig { model: fixed_model, ..Default::default() });
        let adaptive_model = ServiceModel { rebalance: true, ..fixed_model };
        let adaptive =
            sim::run(&trace, &SimConfig { model: adaptive_model, ..Default::default() });
        assert!(
            adaptive.rebalances > 0 && adaptive.replicas_added > 0,
            "adaptive run must execute rebalance rounds"
        );
        assert!(
            adaptive.p99_us() <= fixed.p99_us(),
            "adaptive p99 {:.0}us must not exceed fixed-replication p99 {:.0}us",
            adaptive.p99_us(),
            fixed.p99_us()
        );
        let mut fields = report_fields(&trace, &adaptive);
        fields.push(("rebalances", adaptive.rebalances as f64, "count"));
        fields.push(("replicas_added", adaptive.replicas_added as f64, "count"));
        fields.push(("replicas_dropped", adaptive.replicas_dropped as f64, "count"));
        fields.push(("migrated_bytes", adaptive.migrated_bytes as f64, "bytes"));
        fields.push(("p99_us_fixed", fixed.p99_us(), "us"));
        fields.push(("p999_us_fixed", fixed.p999_us(), "us"));
        fields.push((
            "p99_gain_x",
            fixed.p99_us() / adaptive.p99_us().max(1e-9),
            "x",
        ));
        emit(&mut bench, &mut sink, "rebalance/zipf_hot_tail", &fields);
    }

    // Delta-update row: ship version n+1 of an expert as a ternary diff
    // against resident version n instead of a full re-push. Real
    // pipeline, not a model: compress both checkpoints at paper-scale
    // density, diff in the ternary domain, and measure the wire bytes —
    // asserting the delta stays ≤ 1/4 of the full push and that applying
    // it reconstructs the full encode bit-for-bit.
    {
        let n = 200_000usize;
        let mut rng = Pcg::seed(seed);
        let mut old_tv = ParamSet::new();
        let data: Vec<f32> = (0..n)
            .map(|_| {
                let v = rng.normal_ms(0.0, 7e-4) as f32;
                if rng.next_f32() < 0.01 { v * 20.0 } else { v }
            })
            .collect();
        old_tv.insert("layers.0.attn.lora_a", Tensor::new(vec![n], data));
        // One more training round: ~0.5% of entries move, a sprinkling
        // of sign flips and dropouts — the regime delta updates target.
        let mut new_tv = old_tv.clone();
        for (_, t) in new_tv.iter_mut() {
            let len = t.data.len();
            for k in 0..len / 200 {
                let i = (k * 97 + 13) % len;
                t.data[i] = -t.data[i] * 1.5 + 1e-4;
            }
            for k in 0..len / 800 {
                let i = (k * 211 + 5) % len;
                t.data[i] = 0.0;
            }
        }
        let cfg = CompressConfig { density: 0.05, alpha: 1.0, granularity: Granularity::Global };
        let old_c = compress_params(&old_tv, &cfg);
        let new_c = compress_params(&new_tv, &cfg);
        let delta = compress_delta(&old_c, &new_c).expect("same-shape delta");
        assert_eq!(
            apply_delta(&old_c, &delta).expect("apply"),
            new_c,
            "delta apply must reconstruct the next version bit-for-bit"
        );
        let wire = delta.to_bytes(Encoding::Golomb);
        let full = to_bytes(&new_c, Encoding::Golomb);
        assert!(
            wire.len() * 4 <= full.len(),
            "delta push {} B must be ≤ 1/4 of the full push {} B",
            wire.len(),
            full.len()
        );
        let fields: Vec<(&'static str, f64, &'static str)> = vec![
            ("params", n as f64, "count"),
            ("density", cfg.density, "frac"),
            ("touched_entries", delta.nnz() as f64, "count"),
            ("delta_bytes", wire.len() as f64, "bytes"),
            ("full_bytes", full.len() as f64, "bytes"),
            ("bytes_saved", (full.len() - wire.len()) as f64, "bytes"),
            ("push_shrink_x", full.len() as f64 / (wire.len() as f64).max(1.0), "x"),
        ];
        emit(&mut bench, &mut sink, "delta/update_bytes", &fields);
    }

    if let Some(s) = &sink {
        s.write()?;
        println!("wrote JSON artifact");
    }
    Ok(())
}
