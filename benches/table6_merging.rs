//! Table 6 (§3.7): merging compressed vs original checkpoints — simple
//! Averaging, Task Arithmetic, TIES-Merging, and ComPEFT+TA /
//! ComPEFT+TIES over the 7 GLUE-analog experts; merged model evaluated
//! on all 7 tasks (average accuracy).
//!
//! Also reports the **dense-vs-ternary-domain engine comparison**: for
//! each merge method, the wall time and measured peak heap bytes of
//! (a) decompress-every-expert-then-merge (the reference) against
//! (b) `merging::ternary` serial and (c) `engine::par_merge` on a
//! pool — with the outputs cross-checked for equality on every run.
//! These rows need no artifacts and run in CI via `--quick`.
//!
//! Run: `cargo bench --bench table6_merging`            (full, artifacts)
//!      `cargo bench --bench table6_merging -- --quick` (engine rows only)

use compeft::bench_support as bs;
use compeft::compeft::compress::{
    compress_params, decompress_params, CompressConfig, CompressedParamSet,
    Granularity,
};
use compeft::compeft::engine::par_merge;
use compeft::coordinator::registry::ExpertMethod;
use compeft::merging::ternary::merge_ternary;
use compeft::merging::{
    average, merge_dense, task_arithmetic, ties::ties_merge, ties::TiesConfig,
    MergeMethod,
};
use compeft::tensor::{ParamSet, Tensor};
use compeft::util::bench::{measure_peak, Bench, PeakAlloc};
use compeft::util::pool::ThreadPool;
use compeft::util::prop;
use compeft::util::rng::Pcg;

#[global_allocator]
static ALLOC: PeakAlloc = PeakAlloc;

const GLUE: [&str; 7] = ["mnli", "rte", "qnli", "wnli", "sst2", "mrpc", "qqp"];
const TA_LAMBDAS: [f64; 4] = [0.2, 0.3, 0.5, 1.0];

/// Dense-vs-ternary engine rows on a synthetic expert pool: same
/// numbers, different time and peak memory.
fn engine_comparison(bench: &mut Bench, quick: bool) -> anyhow::Result<()> {
    let d: usize = if quick { 1 << 18 } else { 1 << 22 };
    let n_experts = 7usize;
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    println!(
        "table6 engine comparison: {n_experts} experts x {d} params, \
         k=0.2, pool={workers}"
    );

    let mut rng = Pcg::seed(1206);
    let cfg = CompressConfig {
        density: 0.2,
        alpha: 1.0,
        granularity: Granularity::Global,
    };
    // One structural template for decompression + N compressed experts.
    let mut template = ParamSet::new();
    template.insert("w", Tensor::zeros(vec![d]));
    let comps: Vec<CompressedParamSet> = (0..n_experts)
        .map(|_| {
            let mut p = ParamSet::new();
            p.insert("w", Tensor::new(vec![d], prop::task_vector_like(&mut rng, d)));
            compress_params(&p, &cfg)
        })
        .collect();
    let refs: Vec<&CompressedParamSet> = comps.iter().collect();
    let pool = ThreadPool::new(workers);

    let methods: Vec<(&str, MergeMethod)> = vec![
        ("averaging", MergeMethod::Average),
        ("task_arith", MergeMethod::TaskArithmetic { lambda: 0.3 }),
        ("ties", MergeMethod::Ties { density: 0.2, lambda: 1.0 }),
        (
            "lorahub_w",
            MergeMethod::Weighted {
                weights: (0..n_experts).map(|i| 0.6 - 0.1 * i as f64).collect(),
            },
        ),
    ];
    for (name, method) in &methods {
        // (a) Reference: densify every expert, then merge.
        let (dense_out, dense_s, dense_peak) = measure_peak(|| {
            let dense: Vec<ParamSet> = comps
                .iter()
                .map(|c| decompress_params(c, &template).unwrap())
                .collect();
            merge_dense(&dense, method).unwrap()
        });
        // (b) Ternary-domain, serial.
        let (tern_out, tern_s, tern_peak) =
            measure_peak(|| merge_ternary(&refs, method).unwrap());
        // (c) Ternary-domain, chunk-parallel.
        let (par_out, par_s, par_peak) =
            measure_peak(|| par_merge(&refs, method, &pool).unwrap());

        assert_eq!(dense_out, tern_out, "{name}: ternary != dense reference");
        assert_eq!(dense_out, par_out, "{name}: par != dense reference");

        bench.row(
            &format!("engine/{name}"),
            &[
                ("dense_ms", dense_s * 1e3),
                ("ternary_ms", tern_s * 1e3),
                ("ternary_par_ms", par_s * 1e3),
                ("speedup_serial", dense_s / tern_s.max(1e-12)),
                ("speedup_par", dense_s / par_s.max(1e-12)),
                ("dense_peak_mb", dense_peak as f64 / 1e6),
                ("ternary_peak_mb", tern_peak as f64 / 1e6),
                ("ternary_par_peak_mb", par_peak as f64 / 1e6),
            ],
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut bench = Bench::new("table6");

    // Engine rows first: artifact-free, always printed.
    engine_comparison(&mut bench, quick)?;
    if quick {
        return Ok(());
    }

    let artifacts = bs::require_artifacts();
    for scale in ["xs", "s", "m"] {
        if !artifacts.join("models").join(scale).join("base.npz").exists() {
            continue;
        }
        let (_rt, bundle) = bs::load_bundle(&artifacts, scale)?;
        for method in ["ia3", "lora"] {
            // Load all 7 experts (skip the scale/method if incomplete).
            let experts: Vec<bs::Expert> = GLUE
                .iter()
                .filter_map(|t| bs::load_expert(&artifacts, scale, t, method, None).ok())
                .collect();
            if experts.len() < GLUE.len() {
                continue;
            }
            let m = experts[0].method;
            let tvs: Vec<ParamSet> = experts.iter().map(|e| e.tv.clone()).collect();
            let ctvs: Vec<ParamSet> = experts
                .iter()
                .map(|e| {
                    // §3.7 merges the compressed checkpoints; fixed
                    // (k=0.2, α=1) matches the robust large-model recipe.
                    bs::compress_tv(&e.tv, 0.2, 1.0)
                })
                .collect();

            let tests: Vec<_> = GLUE
                .iter()
                .map(|t| bs::load_eval(&artifacts, &format!("glue_{t}")))
                .collect::<anyhow::Result<_>>()?;
            let vals: Vec<_> = GLUE
                .iter()
                .map(|t| bs::load_eval(&artifacts, &format!("glue_{t}_val")))
                .collect::<anyhow::Result<_>>()?;

            let eval_merged = |tv: &ParamSet,
                               sets: &[compeft::eval::EvalSet]|
             -> anyhow::Result<f64> {
                let mut s = 0.0;
                for set in sets {
                    s += bs::eval_tv(&bundle, m, tv, set)?;
                }
                Ok(s / sets.len() as f64)
            };

            // λ tuned on validation for TA (and TIES), per the papers.
            let tune_ta = |tvs: &[ParamSet]| -> anyhow::Result<(f64, f64)> {
                let mut best = (0.0, TA_LAMBDAS[0]);
                for &l in &TA_LAMBDAS {
                    let merged = task_arithmetic(tvs, l)?;
                    let acc = eval_merged(&merged, &vals)?;
                    if acc > best.0 {
                        best = (acc, l);
                    }
                }
                Ok(best)
            };

            // Averaging.
            let avg = eval_merged(&average(&tvs)?, &tests)?;
            // Task arithmetic: original + compressed.
            let (_, l1) = tune_ta(&tvs)?;
            let ta = eval_merged(&task_arithmetic(&tvs, l1)?, &tests)?;
            let (_, l2) = tune_ta(&ctvs)?;
            let cta = eval_merged(&task_arithmetic(&ctvs, l2)?, &tests)?;
            // TIES: original + compressed.
            let ties_cfg = TiesConfig { density: 0.2, lambda: 1.0 };
            let ties = eval_merged(&ties_merge(&tvs, &ties_cfg)?, &tests)?;
            let cties = eval_merged(&ties_merge(&ctvs, &ties_cfg)?, &tests)?;

            bench.row(
                &format!("{scale}/{method}"),
                &[
                    ("averaging", avg * 100.0),
                    ("task_arith", ta * 100.0),
                    ("compeft_ta", cta * 100.0),
                    ("ties", ties * 100.0),
                    ("compeft_ties", cties * 100.0),
                    ("ta_lambda", l1),
                ],
            );
            let _ = ExpertMethod::Lora;
        }
    }
    Ok(())
}
