//! Table 6 (§3.7): merging compressed vs original checkpoints — simple
//! Averaging, Task Arithmetic, TIES-Merging, and ComPEFT+TA /
//! ComPEFT+TIES over the 7 GLUE-analog experts; merged model evaluated
//! on all 7 tasks (average accuracy).
//!
//! Run: `cargo bench --bench table6_merging`

use compeft::bench_support as bs;
use compeft::coordinator::registry::ExpertMethod;
use compeft::merging::{average, task_arithmetic, ties::ties_merge, ties::TiesConfig};
use compeft::tensor::ParamSet;
use compeft::util::bench::Bench;

const GLUE: [&str; 7] = ["mnli", "rte", "qnli", "wnli", "sst2", "mrpc", "qqp"];
const TA_LAMBDAS: [f64; 4] = [0.2, 0.3, 0.5, 1.0];

fn main() -> anyhow::Result<()> {
    let artifacts = bs::require_artifacts();
    let mut bench = Bench::new("table6");

    for scale in ["xs", "s", "m"] {
        if !artifacts.join("models").join(scale).join("base.npz").exists() {
            continue;
        }
        let (_rt, bundle) = bs::load_bundle(&artifacts, scale)?;
        for method in ["ia3", "lora"] {
            // Load all 7 experts (skip the scale/method if incomplete).
            let experts: Vec<bs::Expert> = GLUE
                .iter()
                .filter_map(|t| bs::load_expert(&artifacts, scale, t, method, None).ok())
                .collect();
            if experts.len() < GLUE.len() {
                continue;
            }
            let m = experts[0].method;
            let tvs: Vec<ParamSet> = experts.iter().map(|e| e.tv.clone()).collect();
            let ctvs: Vec<ParamSet> = experts
                .iter()
                .map(|e| {
                    // §3.7 merges the compressed checkpoints; fixed
                    // (k=0.2, α=1) matches the robust large-model recipe.
                    bs::compress_tv(&e.tv, 0.2, 1.0)
                })
                .collect();

            let tests: Vec<_> = GLUE
                .iter()
                .map(|t| bs::load_eval(&artifacts, &format!("glue_{t}")))
                .collect::<anyhow::Result<_>>()?;
            let vals: Vec<_> = GLUE
                .iter()
                .map(|t| bs::load_eval(&artifacts, &format!("glue_{t}_val")))
                .collect::<anyhow::Result<_>>()?;

            let eval_merged = |tv: &ParamSet,
                               sets: &[compeft::eval::EvalSet]|
             -> anyhow::Result<f64> {
                let mut s = 0.0;
                for set in sets {
                    s += bs::eval_tv(&bundle, m, tv, set)?;
                }
                Ok(s / sets.len() as f64)
            };

            // λ tuned on validation for TA (and TIES), per the papers.
            let tune_ta = |tvs: &[ParamSet]| -> anyhow::Result<(f64, f64)> {
                let mut best = (0.0, TA_LAMBDAS[0]);
                for &l in &TA_LAMBDAS {
                    let merged = task_arithmetic(tvs, l)?;
                    let acc = eval_merged(&merged, &vals)?;
                    if acc > best.0 {
                        best = (acc, l);
                    }
                }
                Ok(best)
            };

            // Averaging.
            let avg = eval_merged(&average(&tvs)?, &tests)?;
            // Task arithmetic: original + compressed.
            let (_, l1) = tune_ta(&tvs)?;
            let ta = eval_merged(&task_arithmetic(&tvs, l1)?, &tests)?;
            let (_, l2) = tune_ta(&ctvs)?;
            let cta = eval_merged(&task_arithmetic(&ctvs, l2)?, &tests)?;
            // TIES: original + compressed.
            let ties_cfg = TiesConfig { density: 0.2, lambda: 1.0 };
            let ties = eval_merged(&ties_merge(&tvs, &ties_cfg)?, &tests)?;
            let cties = eval_merged(&ties_merge(&ctvs, &ties_cfg)?, &tests)?;

            bench.row(
                &format!("{scale}/{method}"),
                &[
                    ("averaging", avg * 100.0),
                    ("task_arith", ta * 100.0),
                    ("compeft_ta", cta * 100.0),
                    ("ties", ties * 100.0),
                    ("compeft_ties", cties * 100.0),
                    ("ta_lambda", l1),
                ],
            );
            let _ = ExpertMethod::Lora;
        }
    }
    Ok(())
}
