//! Table 3: compressing (IA)³ and LoRA modules on smaller bases —
//! own-task test accuracy (and sizes) over the 7 GLUE-analog tasks,
//! original vs ComPEFT, across scales xs/s/m (T5-Base / T5-Large /
//! T0-3B analogs).
//!
//! Run: `cargo bench --bench table3_peft`

use compeft::bench_support as bs;
use compeft::util::bench::Bench;

const GLUE: [&str; 7] = ["mnli", "rte", "qnli", "wnli", "sst2", "mrpc", "qqp"];

fn main() -> anyhow::Result<()> {
    let artifacts = bs::require_artifacts();
    let mut bench = Bench::new("table3");

    for scale in ["xs", "s", "m"] {
        if !artifacts.join("models").join(scale).join("base.npz").exists() {
            continue;
        }
        let (_rt, bundle) = bs::load_bundle(&artifacts, scale)?;
        for method in ["ia3", "lora"] {
            let mut rows = Vec::new();
            for task in GLUE {
                let expert =
                    match bs::load_expert(&artifacts, scale, task, method, None) {
                        Ok(e) => e,
                        Err(_) => continue,
                    };
                let test = bs::load_eval(&artifacts, &format!("glue_{task}"))?;
                let val = bs::load_eval(&artifacts, &format!("glue_{task}_val"))?.truncate(160);
                let orig = bs::eval_tv(&bundle, expert.method, &expert.tv, &test)?;
                let grid = bs::sweep_cached(
                    &bundle,
                    &expert,
                    &val,
                    &format!("t3_{scale}_{task}_{method}"),
                )?;
                let best = bs::best_point(&grid);
                let ctv = bs::compress_tv(&expert.tv, best.density, best.alpha);
                let comp = bs::eval_tv(&bundle, expert.method, &ctv, &test)?;
                let orig_b = expert.tv.bytes_fp16();
                let comp_b = bs::compeft_bytes(&expert.tv, best.density, best.alpha);
                bench.row(
                    &format!("{scale}/{method}/{task}"),
                    &[
                        ("orig_acc", orig * 100.0),
                        ("compeft_acc", comp * 100.0),
                        ("orig_kb", orig_b as f64 / 1e3),
                        ("compeft_kb", comp_b as f64 / 1e3),
                        ("ratio", orig_b as f64 / comp_b as f64),
                    ],
                );
                rows.push((orig, comp, orig_b as f64 / comp_b as f64));
            }
            if !rows.is_empty() {
                let n = rows.len() as f64;
                let (so, sc, sr) = rows.iter().fold((0.0, 0.0, 0.0), |a, r| {
                    (a.0 + r.0, a.1 + r.1, a.2 + r.2)
                });
                bench.row(
                    &format!("{scale}/{method}/AVERAGE"),
                    &[
                        ("orig_acc", so / n * 100.0),
                        ("compeft_acc", sc / n * 100.0),
                        ("improvement", (sc - so) / n * 100.0),
                        ("mean_ratio", sr / n),
                    ],
                );
            }
        }
    }
    Ok(())
}
