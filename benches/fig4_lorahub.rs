//! Figure 4 (§3.6): few-shot compositional generalization via LoraHub —
//! zero-shot vs LoraHub(original experts) vs LoraHub(ComPEFT experts)
//! on the BBH-analog compositional tasks, multiple seeds.
//!
//! The composition weights are learned with the gradient-free (1+1)-ES
//! over the few-shot cross-entropy computed through the PJRT runtime —
//! Python is nowhere in this loop.
//!
//! Also reports the **dense-vs-ternary-domain composition comparison**
//! (`--quick`, artifact-free): the same ES run over a synthetic expert
//! pool, composing candidates densely vs ternary-domain
//! (`lorahub::compose_ternary`) — identical learned weights by
//! construction, with time and measured peak-memory rows.
//!
//! Run: `cargo bench --bench fig4_lorahub`             (full, artifacts)
//!      `cargo bench --bench fig4_lorahub -- --quick`  (engine rows only)

use compeft::bench_support as bs;
use compeft::compeft::compress::{
    compress_params, decompress_params, CompressConfig, Granularity,
};
use compeft::coordinator::registry::ExpertMethod;
use compeft::eval::fewshot_loss;
use compeft::merging::es::EsConfig;
use compeft::merging::lorahub::{learn_composition, learn_composition_ternary};
use compeft::runtime::AdapterKind;
use compeft::tensor::{ParamSet, Tensor};
use compeft::util::bench::{measure_peak, Bench, PeakAlloc};
use compeft::util::prop;
use compeft::util::rng::Pcg;
use compeft::util::stats;

#[global_allocator]
static ALLOC: PeakAlloc = PeakAlloc;

/// Dense-vs-ternary LoraHub adaptation on a synthetic pool: the ES
/// trajectory is identical (compositions are bit-identical), so the
/// rows compare pure engine cost.
fn composition_comparison(bench: &mut Bench, quick: bool) -> anyhow::Result<()> {
    let d: usize = if quick { 1 << 16 } else { 1 << 20 };
    let n_experts = 8usize;
    let budget = if quick { 40 } else { 120 };
    println!(
        "fig4 composition comparison: {n_experts} experts x {d} params, \
         ES budget {budget}"
    );

    let mut rng = Pcg::seed(404);
    let cfg = CompressConfig {
        density: 0.2,
        alpha: 1.0,
        granularity: Granularity::Global,
    };
    let mut template = ParamSet::new();
    template.insert("l0.lora_a", Tensor::zeros(vec![d]));
    let comps: Vec<_> = (0..n_experts)
        .map(|_| {
            let mut p = ParamSet::new();
            p.insert(
                "l0.lora_a",
                Tensor::new(vec![d], prop::task_vector_like(&mut rng, d)),
            );
            compress_params(&p, &cfg)
        })
        .collect();
    let refs: Vec<&_> = comps.iter().collect();
    let dense_pool: Vec<ParamSet> = comps
        .iter()
        .map(|c| decompress_params(c, &template).unwrap())
        .collect();

    // Few-shot stand-in objective: distance to the first expert.
    let target = dense_pool[0].flatten();
    let loss = |c: &ParamSet| -> f64 {
        c.flatten()
            .iter()
            .zip(&target)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum()
    };
    let es = EsConfig { budget, restarts: 2, ..Default::default() };

    let mut rng_a = Pcg::seed(7);
    let (dense, dense_s, dense_peak) =
        measure_peak(|| learn_composition(&dense_pool, &es, &mut rng_a, loss));
    let dense = dense?;

    let mut rng_b = Pcg::seed(7);
    let (tern, tern_s, tern_peak) =
        measure_peak(|| learn_composition_ternary(&refs, &es, &mut rng_b, loss));
    let tern = tern?;

    assert_eq!(dense.weights, tern.weights, "ES trajectories must match");
    assert_eq!(dense.composed, tern.composed);

    bench.row(
        "engine/lorahub_adapt",
        &[
            ("dense_ms", dense_s * 1e3),
            ("ternary_ms", tern_s * 1e3),
            ("evals", dense.evals as f64),
            ("dense_peak_mb", dense_peak as f64 / 1e6),
            ("ternary_peak_mb", tern_peak as f64 / 1e6),
            // The dense-pool working set the ternary path never pays.
            ("dense_pool_mb", (n_experts * d * 4) as f64 / 1e6),
        ],
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut bench = Bench::new("fig4");
    composition_comparison(&mut bench, quick)?;
    if quick {
        return Ok(());
    }
    let artifacts = bs::require_artifacts();
    let scale = std::env::var("COMPEFT_SCALE").unwrap_or_else(|_| "s".into());
    let seeds: u64 = std::env::var("COMPEFT_SEEDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let n_bbh: usize = std::env::var("COMPEFT_BBH_TASKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);

    if !artifacts.join("models").join(&scale).join("base.npz").exists() {
        return Ok(());
    }
    let (_rt, bundle) = bs::load_bundle(&artifacts, &scale)?;

    // Expert pool: the pretrain-rule LoRA experts (LoraHub's "~200
    // upstream task" pool, scaled down to our suite).
    let mut pool: Vec<ParamSet> = Vec::new();
    for i in 0..12 {
        if let Ok(e) =
            bs::load_expert(&artifacts, &scale, &format!("pre{i:02}"), "lora", None)
        {
            pool.push(e.tv);
        }
    }
    if pool.is_empty() {
        eprintln!("no pretrain-rule expert pool at scale {scale}; skipping");
        return Ok(());
    }
    println!("expert pool: {} LoRA modules", pool.len());

    // Adapter = init + Σ w_i tv_i; compose over tvs then add init.
    let materialize = |tv: &ParamSet| -> ParamSet {
        let mut a = (*bundle.lora_init).clone();
        a.add_assign(tv).unwrap();
        a
    };
    let comp_pool: Vec<ParamSet> =
        pool.iter().map(|tv| bs::compress_tv(tv, 0.2, 1.0)).collect();

    let es_budget: usize = std::env::var("COMPEFT_ES_BUDGET")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);

    let mut per_variant: Vec<Vec<f64>> = vec![Vec::new(); 3]; // zs, lorahub, compeft
    let mut names = Vec::new();
    for i in 0..n_bbh {
        let task = format!("bbh{i:02}");
        let test = match bs::load_eval(&artifacts, &format!("bbh_{task}")) {
            Ok(s) => s,
            Err(_) => continue,
        };
        let fewshot = bs::load_eval(&artifacts, &format!("bbh_{task}_fewshot"))?;

        // Zero-shot baseline.
        let zs = compeft::eval::evaluate(
            &bundle,
            AdapterKind::Base,
            bs::EVAL_BATCH,
            None,
            None,
            &test,
        )?;
        per_variant[0].push(zs);

        for (variant, experts) in [(1usize, &pool), (2usize, &comp_pool)] {
            let mut accs = Vec::new();
            for seed in 0..seeds {
                let mut rng = Pcg::seed(1000 + seed);
                let cfg = EsConfig {
                    budget: es_budget,
                    restarts: 2,
                    l1: 0.05,
                    ..Default::default()
                };
                let result = learn_composition(experts, &cfg, &mut rng, |tv| {
                    let adapter = materialize(tv);
                    fewshot_loss(&bundle, AdapterKind::Lora, bs::EVAL_BATCH, &adapter, &fewshot)
                        .unwrap_or(f64::INFINITY)
                })?;
                let acc = bs::eval_tv(
                    &bundle,
                    ExpertMethod::Lora,
                    &result.composed,
                    &test,
                )?;
                accs.push(acc);
            }
            per_variant[variant].push(stats::mean(&accs));
        }
        names.push(task.clone());
        bench.row(
            &format!("{scale}/{task}"),
            &[
                ("zeroshot", per_variant[0].last().unwrap() * 100.0),
                ("lorahub_orig", per_variant[1].last().unwrap() * 100.0),
                ("lorahub_compeft", per_variant[2].last().unwrap() * 100.0),
            ],
        );
    }

    if !names.is_empty() {
        bench.row(
            &format!("{scale}/AVERAGE"),
            &[
                ("zeroshot", stats::mean(&per_variant[0]) * 100.0),
                ("lorahub_orig", stats::mean(&per_variant[1]) * 100.0),
                ("lorahub_compeft", stats::mean(&per_variant[2]) * 100.0),
                ("orig_std", stats::std(&per_variant[1]) * 100.0),
                ("compeft_std", stats::std(&per_variant[2]) * 100.0),
            ],
        );
    }
    Ok(())
}
