//! Table 8 (Appendix C.1): ComPEFT vs STC vs BitDelta (No Training) vs
//! DAREx-q on the synthetic-MMLU benchmark + encoded sizes, using the
//! same storage accounting as the paper (Golomb for ComPEFT/STC,
//! bitmask for BitDelta, COO for DAREx).
//!
//! Run: `cargo bench --bench table8_baselines`

use compeft::baselines::bitdelta::{bitdelta_bytes, bitdelta_compress};
use compeft::baselines::darex::{dare_compress, DareConfig};
use compeft::baselines::stc::stc_compress;
use compeft::bench_support as bs;
use compeft::compeft::golomb;
use compeft::coordinator::registry::ExpertMethod;
use compeft::tensor::ParamSet;
use compeft::util::bench::Bench;
use compeft::util::rng::Pcg;

fn from_flat(like: &ParamSet, flat: &[f32]) -> ParamSet {
    like.unflatten_like(flat).unwrap()
}

fn main() -> anyhow::Result<()> {
    let artifacts = bs::require_artifacts();
    let mut bench = Bench::new("table8");
    let scale = std::env::var("COMPEFT_SCALE").unwrap_or_else(|_| "m".into());
    let tasks = ["alpaca", "chip2", "longform", "guanaco", "self-instruct"];

    if !artifacts.join("models").join(&scale).join("base.npz").exists() {
        eprintln!("scale {scale} missing");
        return Ok(());
    }
    let (_rt, bundle) = bs::load_bundle(&artifacts, &scale)?;
    let test = bs::load_eval(&artifacts, "heldout_bench")?.truncate(640);
    let val = bs::load_eval(&artifacts, "heldout_bench_val")?.truncate(320);

    let mut sums = vec![0.0f64; 7];
    let mut sizes = vec![0.0f64; 7];
    let mut n = 0.0;
    for task in tasks {
        let expert = match bs::load_expert(&artifacts, &scale, task, "lora", None) {
            Ok(e) => e,
            Err(_) => continue,
        };
        let flat = expert.tv.flatten();
        let d = flat.len();

        // Original.
        let orig = bs::eval_tv(&bundle, ExpertMethod::Lora, &expert.tv, &test)?;

        // ComPEFT (validation-tuned k, α).
        let grid =
            bs::sweep_cached(&bundle, &expert, &val, &format!("t1_{scale}_{task}"))?;
        let best = bs::best_point(&grid);
        let ctv = bs::compress_tv(&expert.tv, best.density, best.alpha);
        let compeft = bs::eval_tv(&bundle, ExpertMethod::Lora, &ctv, &test)?;
        let compeft_b = bs::compeft_bytes(&expert.tv, best.density, best.alpha);

        // STC at the same density.
        let stc_t = stc_compress(&flat, best.density);
        let stc_acc = bs::eval_tv(
            &bundle,
            ExpertMethod::Lora,
            &from_flat(&expert.tv, &stc_t.to_dense()),
            &test,
        )?;
        let stc_b = golomb::encode(&stc_t).len() as u64;

        // BitDelta (No Training).
        let bd = bitdelta_compress(&flat);
        let bd_acc = bs::eval_tv(
            &bundle,
            ExpertMethod::Lora,
            &from_flat(&expert.tv, &bd.to_dense()),
            &test,
        )?;
        let bd_b = bitdelta_bytes(d);

        // DAREx p=0.95 and p=0.99 (unbiased 1/q rescale).
        let mut rng = Pcg::seed(17);
        let mut dare_res = Vec::new();
        for p in [0.95, 0.99] {
            let s = dare_compress(
                &flat,
                &DareConfig { drop_p: p, q_scale: None },
                &mut rng,
            );
            let acc = bs::eval_tv(
                &bundle,
                ExpertMethod::Lora,
                &from_flat(&expert.tv, &s.to_dense()),
                &test,
            )?;
            dare_res.push((acc, s.coo_bytes()));
        }

        bench.row(
            &format!("{scale}/{task}"),
            &[
                ("original", orig * 100.0),
                ("compeft", compeft * 100.0),
                ("stc", stc_acc * 100.0),
                ("bitdelta_nt", bd_acc * 100.0),
                ("darex_p95", dare_res[0].0 * 100.0),
                ("darex_p99", dare_res[1].0 * 100.0),
            ],
        );
        let row_sizes = [
            expert.tv.bytes_fp16() as f64,
            compeft_b as f64,
            stc_b as f64,
            bd_b as f64,
            dare_res[0].1 as f64,
            dare_res[1].1 as f64,
        ];
        let accs = [
            orig,
            compeft,
            stc_acc,
            bd_acc,
            dare_res[0].0,
            dare_res[1].0,
        ];
        for i in 0..6 {
            sums[i] += accs[i];
            sizes[i] += row_sizes[i];
        }
        n += 1.0;
    }
    if n > 0.0 {
        bench.row(
            &format!("{scale}/AVERAGE"),
            &[
                ("original", sums[0] / n * 100.0),
                ("compeft", sums[1] / n * 100.0),
                ("stc", sums[2] / n * 100.0),
                ("bitdelta_nt", sums[3] / n * 100.0),
                ("darex_p95", sums[4] / n * 100.0),
                ("darex_p99", sums[5] / n * 100.0),
            ],
        );
        bench.row(
            &format!("{scale}/SIZE_KB"),
            &[
                ("original", sizes[0] / n / 1e3),
                ("compeft", sizes[1] / n / 1e3),
                ("stc", sizes[2] / n / 1e3),
                ("bitdelta_nt", sizes[3] / n / 1e3),
                ("darex_p95", sizes[4] / n / 1e3),
                ("darex_p99", sizes[5] / n / 1e3),
            ],
        );
    }
    Ok(())
}
