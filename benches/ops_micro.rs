//! Micro-benchmarks for the §2.2 operational claims: Golomb coding
//! throughput, XOR+POPCNT distance, AND-based ternary dot product vs a
//! dense f32 baseline, and end-to-end Algorithm 1 compression speed.
//!
//! Run: `cargo bench --bench ops_micro`
//!      `cargo bench --bench ops_micro -- --quick` (256K-param vectors,
//!      5 iterations — the CI smoke shape; writes `BENCH_decode.json`
//!      unless `--json <path>` picks another artifact location)
//!      `... -- --quick --json BENCH_ops_micro.json` (machine-readable
//!      `{bench, row, value, unit, config}` records)

use compeft::compeft::bitmask::MaskPair;
use compeft::compeft::compress::{compress_vector, CompressConfig};
use compeft::compeft::engine::par_compress_vector;
use compeft::compeft::{golomb, ternary::TernaryVector};
use compeft::util::bench::{black_box, json_flag, Bench, JsonSink, Measurement};
use compeft::util::json::Json;
use compeft::util::pool::ThreadPool;
use compeft::util::rng::Pcg;

fn random_tv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::seed(seed);
    (0..n).map(|_| rng.normal() as f32 * 0.01).collect()
}

/// Time a throughput case and mirror it into the `--json` sink.
fn runt<F: FnMut()>(
    b: &mut Bench,
    sink: &mut Option<JsonSink>,
    name: &str,
    bytes: u64,
    f: F,
) -> Measurement {
    let m = b.run_throughput(name, bytes, f);
    if let Some(s) = sink {
        let mean = m.mean.as_secs_f64();
        s.record(&format!("{name}/mean_s"), mean, "s");
        s.record(&format!("{name}/mb_per_s"), bytes as f64 / mean.max(1e-12) / 1e6, "MB/s");
    }
    m
}

/// Print a free-form row and mirror it into the `--json` sink.
fn row(b: &mut Bench, sink: &mut Option<JsonSink>, label: &str, fields: &[(&str, f64)]) {
    b.row(label, fields);
    if let Some(s) = sink {
        for (k, v) in fields {
            s.record(&format!("{label}/{k}"), *v, "value");
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let d: usize = if quick { 1 << 18 } else { 1 << 22 };
    let sz = if quick { "256K" } else { "4M" };
    // `--quick` is the CI smoke shape: it always leaves a
    // machine-readable artifact behind (`BENCH_decode.json` unless
    // `--json` chose a path) so the perf gate has something to assert.
    let json_path = json_flag(&args).or_else(|| {
        quick.then(|| std::path::PathBuf::from("BENCH_decode.json"))
    });
    let mut sink = json_path.map(|path| {
        let mut config = Json::obj();
        config
            .set("quick", Json::Bool(quick))
            .set("d", Json::num(d as f64));
        JsonSink::new(path, "ops_micro", config)
    });
    let sink = &mut sink;
    let mut b = Bench::new("ops_micro");
    if quick {
        b = b.iters(5).warmup(1);
    }
    let tau = random_tv(d, 7);
    let bytes_dense = (d * 4) as u64;

    // Algorithm 1 end to end (the compressor's hot path).
    let cfg = CompressConfig { density: 0.05, alpha: 1.0, ..Default::default() };
    let serial = runt(&mut b, sink, &format!("compress_{sz}_k5"), bytes_dense, || {
        black_box(compress_vector(&tau, &cfg));
    });

    let tern = compress_vector(&tau, &cfg);

    // Parallel chunked engine: worker-count scaling on the same τ.
    // Output is bit-identical to the serial path (asserted below); the
    // interesting number is the speedup at 8 workers.
    let mut par_means = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(workers);
        let m = runt(
            &mut b,
            sink,
            &format!("par_compress_{sz}_k5_w{workers}"),
            bytes_dense,
            || {
                black_box(par_compress_vector(&tau, &cfg, &pool));
            },
        );
        par_means.push((workers, m.mean.as_secs_f64()));
        let par_tern = par_compress_vector(&tau, &cfg, &pool);
        assert_eq!(par_tern.plus, tern.plus, "parallel engine diverged (w={workers})");
        assert_eq!(par_tern.minus, tern.minus);
        assert_eq!(par_tern.scale.to_bits(), tern.scale.to_bits());
    }
    let serial_mean = serial.mean.as_secs_f64();
    let labels: Vec<String> =
        par_means.iter().map(|&(w, _)| format!("w{w}")).collect();
    let speedups: Vec<(&str, f64)> = labels
        .iter()
        .zip(&par_means)
        .map(|(label, &(_, mean))| (label.as_str(), serial_mean / mean))
        .collect();
    row(&mut b, sink, "par_compress_speedup_vs_serial", &speedups);

    // Parallel Golomb encode of the plus/minus streams.
    let pool8 = ThreadPool::new(8);
    runt(&mut b, sink, &format!("golomb_encode_par_{sz}_k5_w8"), bytes_dense, || {
        black_box(golomb::encode_par(&tern, &pool8, 1 << 15));
    });
    assert_eq!(golomb::encode_par(&tern, &pool8, 1 << 15), golomb::encode(&tern));

    // Golomb encode / decode.
    let encoded = golomb::encode(&tern);
    runt(&mut b, sink, &format!("golomb_encode_{sz}_k5"), bytes_dense, || {
        black_box(golomb::encode(&tern));
    });
    let serial_decode =
        runt(&mut b, sink, &format!("golomb_decode_{sz}_k5"), bytes_dense, || {
            black_box(golomb::decode(&encoded).unwrap());
        });

    // Bit-at-a-time oracle loop: the pre-word-kernel decoder, kept as
    // the baseline the branchless 64-bit window kernel is measured
    // against (and differentially tested against in `golomb`/`bitio`).
    let bitwise_decode =
        runt(&mut b, sink, &format!("golomb_decode_bitloop_{sz}_k5"), bytes_dense, || {
            black_box(golomb::decode_bitwise(&encoded).unwrap());
        });
    assert_eq!(
        golomb::decode_bitwise(&encoded).unwrap(),
        tern,
        "bit-loop oracle diverged from the word kernel"
    );
    row(
        &mut b,
        sink,
        "word_decode_speedup_vs_bitloop",
        &[("x", bitwise_decode.mean.as_secs_f64() / serial_decode.mean.as_secs_f64())],
    );

    // Parallel framed decode: worker-count scaling on the same payload
    // through the v2 frame table (the serving-path swap-in decode).
    // Bit-identical to the serial decoder (asserted below).
    let table = golomb::frame_table(&tern, compeft::compeft::format::FRAME_NNZ);
    let mut dec_means = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(workers);
        let m = runt(
            &mut b,
            sink,
            &format!("par_decode_{sz}_k5_w{workers}"),
            bytes_dense,
            || {
                black_box(golomb::decode_par(&encoded, &table, &pool).unwrap());
            },
        );
        dec_means.push((workers, m.mean.as_secs_f64()));
        let par_tern = golomb::decode_par(&encoded, &table, &pool).unwrap();
        assert_eq!(par_tern, tern, "parallel decode diverged (w={workers})");
    }
    let serial_dec_mean = serial_decode.mean.as_secs_f64();
    let dec_labels: Vec<String> =
        dec_means.iter().map(|&(w, _)| format!("w{w}")).collect();
    let dec_speedups: Vec<(&str, f64)> = dec_labels
        .iter()
        .zip(&dec_means)
        .map(|(label, &(_, mean))| (label.as_str(), serial_dec_mean / mean))
        .collect();
    row(&mut b, sink, "par_decode_speedup_vs_serial", &dec_speedups);

    row(
        &mut b,
        sink,
        "golomb_size",
        &[
            ("dense_mb", bytes_dense as f64 / 1e6),
            ("encoded_mb", encoded.len() as f64 / 1e6),
            ("ratio_vs_fp16", (d * 2) as f64 / encoded.len() as f64),
        ],
    );

    // Mask-pair ops vs dense reference (paper: "two machine instructions
    // per 64 parameters").
    let tern2 = compress_vector(&random_tv(d, 8), &cfg);
    let (ma, mb) = (MaskPair::from_ternary(&tern), MaskPair::from_ternary(&tern2));
    runt(&mut b, sink, &format!("mask_xor_popcnt_distance_{sz}"), bytes_dense, || {
        black_box(ma.ternary_l1_distance(&mb).unwrap());
    });
    runt(&mut b, sink, &format!("mask_and_dot_{sz}"), bytes_dense, || {
        black_box(ma.dot(&mb).unwrap());
    });

    // Dense f32 dot product baseline over the same logical vectors.
    let da = tern.to_dense();
    let db = tern2.to_dense();
    runt(&mut b, sink, &format!("dense_f32_dot_{sz}"), bytes_dense * 2, || {
        let mut acc = 0.0f64;
        for (x, y) in da.iter().zip(&db) {
            acc += (*x as f64) * (*y as f64);
        }
        black_box(acc);
    });

    // Decompress (sparse add into dense) — the serving decode path.
    runt(&mut b, sink, &format!("decompress_add_into_{sz}"), bytes_dense, || {
        let mut buf = vec![0.0f32; d];
        tern.add_into(&mut buf, 1.0);
        black_box(buf);
    });

    // Mask round-trips (wire conversions).
    runt(&mut b, sink, &format!("mask_from_ternary_{sz}"), bytes_dense, || {
        black_box(MaskPair::from_ternary(&tern));
    });
    let as_bytes = ma.to_bytes();
    runt(&mut b, sink, &format!("mask_decode_{sz}"), as_bytes.len() as u64, || {
        black_box(MaskPair::from_bytes(&as_bytes).unwrap());
    });
    runt(&mut b, sink, &format!("mask_to_ternary_{sz}"), bytes_dense, || {
        black_box(ma.to_ternary());
    });
    runt(&mut b, sink, &format!("mask_to_ternary_par_{sz}_w8"), bytes_dense, || {
        black_box(ma.to_ternary_par(&pool8, 1 << 13));
    });
    assert_eq!(ma.to_ternary_par(&pool8, 1 << 13), ma.to_ternary());

    // Sanity cross-check while we are here: fast ops equal references.
    let fast = ma.dot(&mb).unwrap();
    let slow: f64 =
        da.iter().zip(&db).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
    assert!((fast - slow).abs() <= 1e-6 * (1.0 + slow.abs()) + 1e-6);
    let _ = TernaryVector::empty(0);

    if let Some(s) = sink.as_ref() {
        s.write().expect("write --json artifact");
    }
}
