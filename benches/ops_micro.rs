//! Micro-benchmarks for the §2.2 operational claims: Golomb coding
//! throughput, XOR+POPCNT distance, AND-based ternary dot product vs a
//! dense f32 baseline, and end-to-end Algorithm 1 compression speed.
//!
//! Run: `cargo bench --bench ops_micro`

use compeft::compeft::bitmask::MaskPair;
use compeft::compeft::compress::{compress_vector, CompressConfig};
use compeft::compeft::{golomb, ternary::TernaryVector};
use compeft::util::bench::{black_box, Bench};
use compeft::util::rng::Pcg;

fn random_tv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::seed(seed);
    (0..n).map(|_| rng.normal() as f32 * 0.01).collect()
}

fn main() {
    let mut b = Bench::new("ops_micro");
    let d = 1 << 22; // 4M params ≈ a real LoRA module
    let tau = random_tv(d, 7);
    let bytes_dense = (d * 4) as u64;

    // Algorithm 1 end to end (the compressor's hot path).
    let cfg = CompressConfig { density: 0.05, alpha: 1.0, ..Default::default() };
    b.run_throughput("compress_4M_k5", bytes_dense, || {
        black_box(compress_vector(&tau, &cfg));
    });

    let tern = compress_vector(&tau, &cfg);

    // Golomb encode / decode.
    let encoded = golomb::encode(&tern);
    b.run_throughput("golomb_encode_4M_k5", bytes_dense, || {
        black_box(golomb::encode(&tern));
    });
    b.run_throughput("golomb_decode_4M_k5", bytes_dense, || {
        black_box(golomb::decode(&encoded).unwrap());
    });
    b.row(
        "golomb_size",
        &[
            ("dense_mb", bytes_dense as f64 / 1e6),
            ("encoded_mb", encoded.len() as f64 / 1e6),
            ("ratio_vs_fp16", (d * 2) as f64 / encoded.len() as f64),
        ],
    );

    // Mask-pair ops vs dense reference (paper: "two machine instructions
    // per 64 parameters").
    let tern2 = compress_vector(&random_tv(d, 8), &cfg);
    let (ma, mb) = (MaskPair::from_ternary(&tern), MaskPair::from_ternary(&tern2));
    b.run_throughput("mask_xor_popcnt_distance_4M", bytes_dense, || {
        black_box(ma.ternary_l1_distance(&mb).unwrap());
    });
    b.run_throughput("mask_and_dot_4M", bytes_dense, || {
        black_box(ma.dot(&mb).unwrap());
    });

    // Dense f32 dot product baseline over the same logical vectors.
    let da = tern.to_dense();
    let db = tern2.to_dense();
    b.run_throughput("dense_f32_dot_4M", bytes_dense * 2, || {
        let mut acc = 0.0f64;
        for (x, y) in da.iter().zip(&db) {
            acc += (*x as f64) * (*y as f64);
        }
        black_box(acc);
    });

    // Decompress (sparse add into dense) — the serving decode path.
    b.run_throughput("decompress_add_into_4M", bytes_dense, || {
        let mut buf = vec![0.0f32; d];
        tern.add_into(&mut buf, 1.0);
        black_box(buf);
    });

    // Mask round-trips (wire conversions).
    b.run_throughput("mask_from_ternary_4M", bytes_dense, || {
        black_box(MaskPair::from_ternary(&tern));
    });
    let as_bytes = ma.to_bytes();
    b.run_throughput("mask_decode_4M", as_bytes.len() as u64, || {
        black_box(MaskPair::from_bytes(&as_bytes).unwrap());
    });

    // Sanity cross-check while we are here: fast ops equal references.
    let fast = ma.dot(&mb).unwrap();
    let slow: f64 =
        da.iter().zip(&db).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
    assert!((fast - slow).abs() <= 1e-6 * (1.0 + slow.abs()) + 1e-6);
    let _ = TernaryVector::empty(0);
}
