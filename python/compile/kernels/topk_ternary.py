"""Pallas kernel: fused ternarization (the elementwise hot loop of
Algorithm 1).

Given a task-vector block, a magnitude threshold, and the shared scale
``s = alpha * sigma(tau)``, emit ``s * sign(tau) * (|tau| >= thr)``.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper leaves
ternary kernels to future CUDA/Triton work (§A). On TPU the natural
mapping is a VPU-elementwise pass tiled in (8, 128) lanes: each grid
step streams one (BLOCK_ROWS, 128) tile HBM→VMEM, applies the
sign/threshold/scale fusion in registers, and streams it back. The
threshold and scale ride along in SMEM-like (1,1) blocks. VMEM
footprint per step: 2 tiles * BLOCK_ROWS*128*4B = 256 KB at
BLOCK_ROWS=256 — well inside the ~16 MB VMEM budget, leaving room for
double buffering (see EXPERIMENTS.md §Perf).

The kernel MUST run with ``interpret=True`` on this CPU image: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256  # (256, 128) f32 tile = 128 KB in VMEM


def _kernel(tau_ref, thr_ref, scale_ref, out_ref):
    tau = tau_ref[...]
    thr = thr_ref[0, 0]
    scale = scale_ref[0, 0]
    keep = (jnp.abs(tau) >= thr).astype(tau.dtype)
    out_ref[...] = scale * jnp.sign(tau) * keep


def ternarize_2d(tau2d, threshold, scale):
    """Ternarize a (rows, LANES) array; rows must divide BLOCK_ROWS grid.

    Internal entry point — use :func:`ternarize` for arbitrary shapes.
    """
    rows, lanes = tau2d.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, (rows, lanes)
    grid = (rows // BLOCK_ROWS,)
    thr = jnp.asarray(threshold, jnp.float32).reshape(1, 1)
    sc = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(tau2d.shape, tau2d.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(tau2d, thr, sc)


def ternarize(tau, threshold, scale):
    """Ternarize an arbitrary-shape f32 array via the Pallas kernel.

    Pads the flattened input to a (n_tiles*BLOCK_ROWS, LANES) panel,
    runs the tiled kernel, and strips the padding. Padding values are 0
    and ternarize(0) == 0, so the pad region is inert.
    """
    flat = tau.reshape(-1)
    n = flat.shape[0]
    tile = BLOCK_ROWS * LANES
    padded = ((n + tile - 1) // tile) * tile
    flat = jnp.pad(flat, (0, padded - n))
    out = ternarize_2d(flat.reshape(-1, LANES), threshold, scale)
    return out.reshape(-1)[:n].reshape(tau.shape)


def compress_pallas(tau, density, alpha):
    """Algorithm 1 with the Pallas kernel as the ternarization step:
    threshold/σ are computed with jnp reductions (they are global
    reductions, not tile-local work), the elementwise pass is the
    kernel. Lowered into the AOT ``compress.hlo.txt`` artifact."""
    from .ref import ref_topk_threshold

    sigma = jnp.std(tau)
    thr = ref_topk_threshold(tau, density)
    return ternarize(tau, thr, alpha * sigma)
