"""Pallas kernel: ternary adapter application via two binary masks.

Computes ``y = x @ (scale * (pos - neg))`` where ``pos``/``neg`` are the
{0,1} float masks of a ComPEFT-compressed weight delta (paper §2.2,
"Efficient Computation ... via Two Binary Vectors"). This is the
compressed serving path's matmul: the coordinator can apply an expert
straight from its mask-pair form without materializing a dense delta.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper
suggests CUDA kernels over warp-level bit operations; the TPU-shaped
equivalent is an MXU systolic-array matmul over mask tiles. We tile
(M, K, N) into MXU-native 128x128 blocks; each grid step loads one x
tile and one mask-pair tile into VMEM, computes ``x @ (p - n)`` on the
MXU, and accumulates into the output tile. The mask subtraction fuses
into the tile load (VPU), so the MXU sees a plain f32 (or bf16) matmul.
VMEM per step: 4 tiles * 64 KB = 256 KB.

``interpret=True`` is mandatory on the CPU image (Mosaic custom-calls
cannot execute on the CPU PJRT plugin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128  # MXU-native tile edge


def _kernel(x_ref, p_ref, n_ref, s_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = p_ref[...] - n_ref[...]  # VPU fuse: ternary digits from masks
    o_ref[...] += s_ref[0, 0] * (x_ref[...] @ w)  # MXU tile matmul


def ternary_matmul_tiled(x, pos, neg, scale):
    """Tiled kernel over shapes already padded to multiples of TILE."""
    m, k = x.shape
    k2, n = pos.shape
    assert k == k2 and pos.shape == neg.shape
    assert m % TILE == 0 and k % TILE == 0 and n % TILE == 0, (m, k, n)
    grid = (m // TILE, n // TILE, k // TILE)
    sc = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, pos, neg, sc)


@functools.partial(jax.jit, static_argnames=())
def ternary_matmul(x, pos, neg, scale):
    """``scale * (x @ (pos - neg))`` for arbitrary shapes (pads to TILE).

    Zero-padding is inert: padded x rows produce discarded output rows,
    padded K entries contribute 0 to every dot product, padded N columns
    are sliced away.
    """
    m, k = x.shape
    _, n = pos.shape
    pm = (-m) % TILE
    pk = (-k) % TILE
    pn = (-n) % TILE
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    pp = jnp.pad(pos, ((0, pk), (0, pn)))
    np_ = jnp.pad(neg, ((0, pk), (0, pn)))
    out = ternary_matmul_tiled(xp, pp, np_, scale)
    return out[:m, :n]
