"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every kernel must match its
oracle (up to float tolerance) under the hypothesis sweeps in
``python/tests/test_kernels.py``. They also serve as the executable
specification of Algorithm 1 that the Rust implementation is
cross-checked against (``python/tests/test_cross_semantics.py`` writes
cases consumed by Rust integration tests).
"""

import jax.numpy as jnp


def ref_ternarize(tau, threshold, scale):
    """Elementwise ternarization: ``scale * sign(tau) * (|tau| >= threshold)``.

    This is the inner loop of Algorithm 1 once the top-k threshold is
    known. Zero entries stay zero (sign(0) == 0).
    """
    keep = (jnp.abs(tau) >= threshold).astype(tau.dtype)
    return scale * jnp.sign(tau) * keep


def ref_topk_threshold(tau, density):
    """Magnitude threshold that keeps ~ceil(density * n) entries.

    Quantile-based — ties at the threshold may keep slightly more than
    ceil(k*n) entries, matching the kernel contract (exact tie-breaking
    is done on the Rust side where compression must be exact).
    """
    mags = jnp.abs(tau).reshape(-1)
    n = mags.shape[0]
    keep = jnp.clip(jnp.ceil(density * n).astype(jnp.int32), 1, n)
    sorted_mags = jnp.sort(mags)  # ascending
    return sorted_mags[n - keep]


def ref_compress(tau, density, alpha):
    """Full Algorithm 1 in jnp: returns the dense ternary approximation
    ``alpha * std(tau) * sign(tau) * topk_mask``."""
    sigma = jnp.std(tau)
    thr = ref_topk_threshold(tau, density)
    return ref_ternarize(tau, thr, alpha * sigma)


def ref_ternary_matmul(x, pos, neg, scale):
    """Adapter application via two binary masks (paper §2.2):

        y = x @ (scale * (pos - neg))

    where ``pos``/``neg`` are the {0,1} float masks of the +1/-1 ternary
    weights. This is the oracle for the ``ternary_apply`` kernel used on
    the compressed serving path.
    """
    return (x @ (pos - neg)) * scale
