"""Synthetic instruction-style task suite.

Substitutes for the paper's datasets (DESIGN.md §3): each *task* is a
fixed rule instance from one of eight rule families, identified by a
dedicated instruction token. Sequences look like

    [BOS, instr, d_1 .. d_14, QUERY, answer]

and the model is scored by rank classification of the answer token at
the QUERY position — mirroring the paper's T5/T0 evaluation protocol.

Benchmarks built from the suite:
  * ``pretrain``      — many rule instances (multitask instruction training)
  * ``heldout_bench`` — unseen examples of a held-out subset of pretrain
                        rules: the "synthetic-MMLU" used for Table 1/2
  * ``instruct_tasks``— 8 *new* rules (new instruction tokens): the
                        QLoRA-style fine-tuning datasets
  * ``glue_tasks``    — 7 rules mirroring GLUE's category mix (Table 3/4/6)
  * ``bbh_tasks``     — 12 compositional rules over unseen instruction
                        pairs (Figure 4's BBH analog)
"""

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from . import config as C


@dataclass
class Task:
    """A fixed rule instance with its own instruction token."""

    name: str
    family: str
    instr_token: int
    n_classes: int
    rule: dict = field(default_factory=dict)

    def generate(self, rng: np.random.Generator, n: int):
        """Return (tokens[n, SEQ_LEN] int32, labels[n] int32)."""
        data = rng.integers(C.DATA_LO, C.DATA_HI, size=(n, C.N_DATA))
        labels = _apply_family(self.family, self.rule, data)
        perm = self.rule.get("answer_perm")
        if perm is not None:
            labels = np.asarray(perm)[labels]
        tokens = np.zeros((n, C.SEQ_LEN), dtype=np.int32)
        tokens[:, 0] = C.BOS
        tokens[:, 1] = self.instr_token
        tokens[:, 2 : 2 + C.N_DATA] = data
        tokens[:, C.QUERY_POS] = C.QUERY
        tokens[:, C.ANSWER_POS] = C.ANSWER_BASE + labels
        return tokens, labels.astype(np.int32)


def _bucket(vals: np.ndarray, c: int) -> np.ndarray:
    """Bucket data-token values into c classes of equal width."""
    span = (C.DATA_HI - C.DATA_LO + c - 1) // c
    return (vals - C.DATA_LO) // span


def _apply_family(family: str, rule: dict, data: np.ndarray) -> np.ndarray:
    """Compute integer labels in [0, n_classes) for a batch of data rows.

    All families are functions of at most two data positions — the kind
    of retrieval/compare structure small attention models learn quickly,
    so fine-tuning converges within a few hundred steps on one CPU core.
    """
    if family == "anchor":
        return _bucket(data[:, rule["pos"]], rule["classes"]).astype(np.int64)
    if family == "above":
        return (data[:, rule["pos"]] >= rule["threshold"]).astype(np.int64)
    if family == "order":
        return (data[:, rule["i"]] < data[:, rule["j"]]).astype(np.int64)
    if family == "eqbucket":
        b = 4
        return (
            _bucket(data[:, rule["i"]], b) == _bucket(data[:, rule["j"]], b)
        ).astype(np.int64)
    if family == "pairsum":
        c = rule["classes"]
        s = data[:, rule["i"]] + data[:, rule["j"]] - 2 * C.DATA_LO
        span = (2 * (C.DATA_HI - C.DATA_LO - 1)) // c + 1
        return (s // span).astype(np.int64)
    if family == "diff":
        c = rule["classes"]
        d = np.abs(data[:, rule["i"]].astype(np.int64) - data[:, rule["j"]])
        span = (C.DATA_HI - C.DATA_LO - 1) // c + 1
        return np.minimum(d // span, c - 1).astype(np.int64)
    if family == "xorbucket":
        bi = _bucket(data[:, rule["i"]], 2)
        bj = _bucket(data[:, rule["j"]], 2)
        return (bi ^ bj).astype(np.int64)
    if family == "firstlast":
        return (data[:, 0] < data[:, -1]).astype(np.int64)
    if family == "compose_and":
        # BBH analog: conjunction of two binary sub-rules.
        a = _apply_family(rule["fam_a"], rule["rule_a"], data)
        b = _apply_family(rule["fam_b"], rule["rule_b"], data)
        return (a & b).astype(np.int64)
    if family == "compose_xor":
        a = _apply_family(rule["fam_a"], rule["rule_a"], data)
        b = _apply_family(rule["fam_b"], rule["rule_b"], data)
        return (a ^ b).astype(np.int64)
    raise ValueError(f"unknown family {family!r}")


FAMILIES = [
    "anchor",
    "above",
    "order",
    "eqbucket",
    "pairsum",
    "diff",
    "xorbucket",
    "firstlast",
]

_BINARY_FAMILIES = ["above", "order", "eqbucket", "xorbucket", "firstlast"]


def _make_rule(family: str, rng: np.random.Generator) -> tuple[dict, int]:
    """Sample rule parameters; returns (rule, n_classes)."""
    if family == "anchor":
        c = int(rng.choice([3, 4]))
        return {"pos": int(rng.integers(0, C.N_DATA)), "classes": c}, c
    if family == "above":
        return {
            "pos": int(rng.integers(0, C.N_DATA)),
            "threshold": int(rng.integers(C.DATA_LO + 30, C.DATA_HI - 30)),
        }, 2
    if family in ("order", "eqbucket", "xorbucket"):
        i, j = rng.choice(C.N_DATA, size=2, replace=False)
        return {"i": int(i), "j": int(j)}, 2
    if family in ("pairsum", "diff"):
        i, j = rng.choice(C.N_DATA, size=2, replace=False)
        c = int(rng.choice([2, 3]))
        return {"i": int(i), "j": int(j), "classes": c}, c
    if family == "firstlast":
        return {}, 2
    raise ValueError(family)


def _task(name: str, family: str, instr: int, rng: np.random.Generator) -> Task:
    rule, n_classes = _make_rule(family, rng)
    # A fixed random permutation of answer tokens per task prevents the
    # model from exploiting global label frequencies.
    rule["answer_perm"] = [int(x) for x in rng.permutation(n_classes)]
    return Task(name, family, instr, n_classes, rule)


# ---------------------------------------------------------------------------
# Suite construction — deterministic from a seed.
# ---------------------------------------------------------------------------

N_PRETRAIN_RULES = 8
N_HELDOUT_BENCH = 8  # benchmark rules, drawn from the *pretrain* rules


def pretrain_tasks(seed: int = 0) -> list[Task]:
    """Multitask instruction-training suite (instruction tokens 200..)."""
    rng = np.random.default_rng(seed + 1000)
    tasks = []
    for i in range(N_PRETRAIN_RULES):
        fam = FAMILIES[i % len(FAMILIES)]
        tasks.append(_task(f"pre{i:02d}", fam, C.INSTR_LO + i, rng))
    return tasks


def heldout_bench_tasks(seed: int = 0) -> list[Task]:
    """The synthetic-MMLU: last N rules of the pretrain suite. They ARE
    trained on (like MMLU's knowledge is in pretraining) but their eval
    examples are fresh; fine-tuning on *other* tasks can degrade them."""
    return pretrain_tasks(seed)[-N_HELDOUT_BENCH:]


INSTRUCT_NAMES = [
    "self-instruct",
    "longform",
    "chip2",
    "hh-rlhf",
    "unnatural",
    "guanaco",
    "alpaca",
    "flan-v2",
]


def instruct_tasks(seed: int = 0) -> list[Task]:
    """8 new rules with NEW instruction tokens — the QLoRA-style
    fine-tuning datasets of Table 1 (names mirror the paper's)."""
    rng = np.random.default_rng(seed + 2000)
    tasks = []
    for i, name in enumerate(INSTRUCT_NAMES):
        fam = FAMILIES[(i * 3 + 1) % len(FAMILIES)]
        tasks.append(_task(name, fam, C.INSTR_LO + N_PRETRAIN_RULES + i, rng))
    return tasks


GLUE_NAMES = ["mnli", "rte", "qnli", "wnli", "sst2", "mrpc", "qqp"]
_GLUE_FAMILIES = [
    "order",      # mnli  (NLI-like pairwise comparison)
    "eqbucket",   # rte
    "order",      # qnli
    "xorbucket",  # wnli  (hard/noisy — mirrors WNLI's difficulty)
    "above",      # sst2  (single-evidence polarity)
    "eqbucket",   # mrpc  (paraphrase-like equality)
    "diff",       # qqp
]


def glue_tasks(seed: int = 0) -> list[Task]:
    """7 tasks mirroring GLUE's category mix (Table 3/4/6)."""
    rng = np.random.default_rng(seed + 3000)
    base = C.INSTR_LO + N_PRETRAIN_RULES + len(INSTRUCT_NAMES)
    return [
        _task(name, fam, base + i, rng)
        for i, (name, fam) in enumerate(zip(GLUE_NAMES, _GLUE_FAMILIES))
    ]


N_BBH = 12


def bbh_tasks(seed: int = 0) -> list[Task]:
    """Compositional generalization suite (Figure 4): each task composes
    two binary sub-rules with AND/XOR under an *unseen* instruction
    token. Solvable by composing pretrain-era skills, matching BBH's
    role in LoraHub."""
    rng = np.random.default_rng(seed + 4000)
    base = C.INSTR_LO + N_PRETRAIN_RULES + len(INSTRUCT_NAMES) + len(GLUE_NAMES)
    tasks = []
    for i in range(N_BBH):
        fam_a = _BINARY_FAMILIES[int(rng.integers(len(_BINARY_FAMILIES)))]
        fam_b = _BINARY_FAMILIES[int(rng.integers(len(_BINARY_FAMILIES)))]
        rule_a, _ = _make_rule(fam_a, rng)
        rule_b, _ = _make_rule(fam_b, rng)
        comp = "compose_and" if i % 2 == 0 else "compose_xor"
        rule = {
            "fam_a": fam_a,
            "rule_a": rule_a,
            "fam_b": fam_b,
            "rule_b": rule_b,
            "answer_perm": [int(x) for x in rng.permutation(2)],
        }
        tasks.append(Task(f"bbh{i:02d}", comp, base + i, 2, rule))
    return tasks


def generate_mixture(
    tasks: list[Task], rng: np.random.Generator, n: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sample a mixed batch across tasks. Returns (tokens, labels, task_idx)."""
    per = np.array_split(np.arange(n), len(tasks))
    toks, labs, tids = [], [], []
    for t, idxs in zip(tasks, per):
        if len(idxs) == 0:
            continue
        x, y = t.generate(rng, len(idxs))
        toks.append(x)
        labs.append(y)
        tids.append(np.full(len(idxs), tasks.index(t), dtype=np.int32))
    tokens = np.concatenate(toks)
    labels = np.concatenate(labs)
    tid = np.concatenate(tids)
    order = rng.permutation(len(tokens))
    return tokens[order], labels[order], tid[order]
