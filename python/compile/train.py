"""Build-time training: pretrain the µT family, fine-tune experts.

Runs ONCE under ``make artifacts`` (idempotent — existing outputs are
skipped) and never on the request path. Produces, per scale:

  artifacts/models/{scale}/base.npz        pretrained base parameters
  artifacts/models/{scale}/lora_init.npz   shared LoRA init (A random, B=0)
  artifacts/models/{scale}/meta.json       config + canonical input order
  artifacts/experts/{scale}/{task}.{method}[.r{rank}].npz
                                           task vector θ_ft − θ_init
  artifacts/experts/{scale}/{task}.{method}[.r{rank}].meta.json
  artifacts/eval/*.npz                     eval sets (tokens/labels/classes)
  artifacts/figure3.json                   PEFT-zoo accuracies (Figure 3)

CLI: ``python -m compile.train [--scales xs,s,m,l] [--stage all]``.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import config as C
from . import model as M
from . import tasks as T


def _log(msg: str) -> None:
    print(f"[train {time.strftime('%H:%M:%S')}] {msg}", flush=True)


def _save_npz(path: str, params: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})
    # np.savez appends .npz if missing; we always pass the full name.


def _load_npz(path: str) -> dict:
    with np.load(path) as z:
        return {k: jnp.asarray(z[k]) for k in z.files}


# ---------------------------------------------------------------------------
# Pretraining
# ---------------------------------------------------------------------------


def pretrain(scale: str, seed: int = 0) -> dict:
    cfg = C.SCALES[scale]
    pre = C.preset()
    out = os.path.join(C.model_dir(scale), "base.npz")
    if os.path.exists(out):
        return _load_npz(out)

    steps = C.pretrain_steps(scale)
    _log(f"pretraining µT-{scale} ({cfg.n_layers}L d{cfg.d_model}) "
         f"for {steps} steps")
    params = M.init_base_params(cfg, seed=seed)
    suite = T.pretrain_tasks()
    rng = np.random.default_rng(seed + 100)
    opt = M.adam_init(params)

    @jax.jit
    def step(params, opt, tokens, answers):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, tokens, answers)
        )(params)
        params, opt = M.adam_update(params, grads, opt, pre.lr_pretrain)
        return params, opt, loss

    for i in range(steps):
        tokens, labels, _ = T.generate_mixture(suite, rng, pre.pretrain_batch)
        answers = C.ANSWER_BASE + labels
        params, opt, loss = step(
            params, opt, jnp.asarray(tokens), jnp.asarray(answers)
        )
        if i % 200 == 0 or i == steps - 1:
            _log(f"  µT-{scale} step {i}: loss {float(loss):.4f}")

    _save_npz(out, params)
    meta = {
        "scale": scale,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "lora_rank": cfg.lora_rank,
        "vocab": C.VOCAB,
        "seq_len": C.SEQ_LEN,
        "n_params": M.param_count(params),
        "base_order": M.export_order(params),
        "lora_order": M.export_order(M.init_lora_params(cfg)),
        "ia3_order": M.export_order(M.init_ia3_params(cfg)),
    }
    with open(os.path.join(C.model_dir(scale), "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return params


# ---------------------------------------------------------------------------
# Fine-tuning
# ---------------------------------------------------------------------------


def _finetune_adapter(cfg, base, task, adapters, kind, lr, steps, batch, seed):
    """Train `adapters` (lora or ia3 dict) on `task`; returns θ_ft."""
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(ad, opt, tokens, answers):
        def loss(a):
            kw = {kind: a}
            return M.loss_fn(cfg, base, tokens, answers, **kw)

        lval, grads = jax.value_and_grad(loss)(ad)
        ad, opt = M.adam_update(ad, grads, opt, lr)
        return ad, opt, lval

    opt = M.adam_init(adapters)
    for _ in range(steps):
        tokens, labels = task.generate(rng, batch)
        ad_ans = C.ANSWER_BASE + labels
        adapters, opt, _ = step(
            adapters, opt, jnp.asarray(tokens), jnp.asarray(ad_ans)
        )
    return adapters


def _finetune_full(cfg, base, task, lr, steps, batch, seed):
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(p, opt, tokens, answers):
        lval, grads = jax.value_and_grad(
            lambda q: M.loss_fn(cfg, q, tokens, answers)
        )(p)
        p, opt = M.adam_update(p, grads, opt, lr)
        return p, opt, lval

    params = dict(base)
    opt = M.adam_init(params)
    for _ in range(steps):
        tokens, labels = task.generate(rng, batch)
        params, opt, _ = step(
            params, opt, jnp.asarray(tokens),
            jnp.asarray(C.ANSWER_BASE + labels),
        )
    return params


def eval_task_accuracy(cfg, base, task, n, seed=1234, lora=None, ia3=None):
    rng = np.random.default_rng(seed)
    tokens, labels = task.generate(rng, n)
    logits = M.forward(cfg, base, jnp.asarray(tokens), lora=lora, ia3=ia3)
    return M.rank_accuracy(logits, jnp.asarray(labels), task.n_classes)


def finetune_expert(scale: str, task: T.Task, method: str, seed: int = 0,
                    rank: int | None = None) -> None:
    """Fine-tune one expert and save its task vector."""
    cfg = C.SCALES[scale]
    pre = C.preset()
    suffix = f".r{rank}" if rank else ""
    stem = os.path.join(C.experts_dir(scale), f"{task.name}.{method}{suffix}")
    if os.path.exists(stem + ".npz"):
        return
    base = pretrain(scale)

    t0 = time.time()
    if method == "lora":
        init = M.init_lora_params(cfg, rank=rank)
        ft = _finetune_adapter(cfg, base, task, dict(init), "lora",
                               pre.lr_lora, pre.finetune_steps,
                               pre.batch_size, seed + 11)
        tv = {k: ft[k] - init[k] for k in ft}
        acc = eval_task_accuracy(cfg, base, task, pre.eval_examples, lora=ft)
    elif method == "ia3":
        init = M.init_ia3_params(cfg)
        ft = _finetune_adapter(cfg, base, task, dict(init), "ia3",
                               pre.lr_ia3, pre.finetune_steps,
                               pre.batch_size, seed + 13)
        tv = {k: ft[k] - init[k] for k in ft}
        acc = eval_task_accuracy(cfg, base, task, pre.eval_examples, ia3=ft)
    elif method == "full":
        ft = _finetune_full(cfg, base, task, pre.lr_full,
                            pre.finetune_steps, pre.batch_size, seed + 17)
        tv = {k: ft[k] - base[k] for k in ft}
        acc = eval_task_accuracy(cfg, ft, task, pre.eval_examples)
    else:
        raise ValueError(f"unknown method {method!r}")

    _save_npz(stem + ".npz", tv)
    flat = np.concatenate([np.asarray(v).reshape(-1) for v in tv.values()])
    meta = {
        "task": task.name,
        "method": method,
        "scale": scale,
        "rank": rank or (cfg.lora_rank if method == "lora" else None),
        "n_classes": task.n_classes,
        "own_task_acc": acc,
        "n_params": int(flat.size),
        "tv_mean": float(flat.mean()),
        "tv_std": float(flat.std()),
        "tv_max": float(flat.max()),
        "tv_min": float(flat.min()),
        "train_seconds": round(time.time() - t0, 2),
    }
    with open(stem + ".meta.json", "w") as f:
        json.dump(meta, f, indent=1)
    _log(f"  expert {scale}/{task.name}.{method}{suffix}: "
         f"own-task acc {acc:.3f} ({meta['train_seconds']}s)")


# ---------------------------------------------------------------------------
# Shared inits + eval sets
# ---------------------------------------------------------------------------


def save_inits(scale: str) -> None:
    cfg = C.SCALES[scale]
    lp = os.path.join(C.model_dir(scale), "lora_init.npz")
    if not os.path.exists(lp):
        _save_npz(lp, M.init_lora_params(cfg))
    ip = os.path.join(C.model_dir(scale), "ia3_init.npz")
    if not os.path.exists(ip):
        _save_npz(ip, M.init_ia3_params(cfg))
    for rank in EXTRA_LORA_RANKS:
        rp = os.path.join(C.model_dir(scale), f"lora_init.r{rank}.npz")
        if not os.path.exists(rp):
            _save_npz(rp, M.init_lora_params(cfg, rank=rank))


def _save_eval_set(name: str, tasks: list, n_per_task: int, seed: int) -> None:
    path = os.path.join(C.eval_dir(), f"{name}.npz")
    if os.path.exists(path):
        return
    rng = np.random.default_rng(seed)
    toks, labs, ncls = [], [], []
    for t in tasks:
        x, y = t.generate(rng, n_per_task)
        toks.append(x)
        labs.append(y)
        ncls.append(np.full(n_per_task, t.n_classes, dtype=np.int64))
    os.makedirs(C.eval_dir(), exist_ok=True)
    np.savez(
        path,
        tokens=np.concatenate(toks).astype(np.int32),
        labels=np.concatenate(labs).astype(np.int64),
        n_classes=np.concatenate(ncls),
    )
    _log(f"  eval set {name}: {sum(len(t) for t in toks)} examples")


def save_eval_sets() -> None:
    pre = C.preset()
    n = pre.eval_examples
    _save_eval_set("heldout_bench", T.heldout_bench_tasks(), n // 2, 501)
    for t in T.instruct_tasks():
        _save_eval_set(f"task_{t.name}", [t], n, 502)
    for t in T.glue_tasks():
        _save_eval_set(f"glue_{t.name}", [t], n, 503)
        _save_eval_set(f"glue_{t.name}_val", [t], n // 2, 504)
    for t in T.bbh_tasks():
        _save_eval_set(f"bbh_{t.name}", [t], n, 505)
        _save_eval_set(f"bbh_{t.name}_fewshot", [t], pre.fewshot_examples, 506)
    _save_eval_set("heldout_bench_val", T.heldout_bench_tasks(), n // 4, 507)


# ---------------------------------------------------------------------------
# Orchestration
# ---------------------------------------------------------------------------

EXTRA_LORA_RANKS = [4, 2]  # Table 10 analog (ranks r, r/2, r/4 via cfg.rank)

# Which scales get which experts (keeps single-core build time sane).
INSTRUCT_SCALES = ["xs", "s", "m", "l"]   # Table 1 / Figure 2
GLUE_SCALES = ["xs", "s", "m"]            # Table 3 (T5-Base/Large, T0-3B analog)
FULLFT_SCALES = ["xs", "s"]               # Table 4
BBH_SCALE = "s"                           # Figure 4
RANK_SCALE = "m"                          # Table 10


def build_all(scales: list[str]) -> None:
    save_eval_sets()
    for scale in scales:
        pretrain(scale)
        save_inits(scale)

    for scale in [s for s in INSTRUCT_SCALES if s in scales]:
        for task in T.instruct_tasks():
            finetune_expert(scale, task, "lora")
    # (IA)3 points for Figure 3 on the zoo tasks.
    for task in T.instruct_tasks()[:4]:
        finetune_expert("s", task, "ia3")

    for scale in [s for s in GLUE_SCALES if s in scales]:
        for task in T.glue_tasks():
            finetune_expert(scale, task, "lora")
            finetune_expert(scale, task, "ia3")

    for scale in [s for s in FULLFT_SCALES if s in scales]:
        for task in T.glue_tasks():
            finetune_expert(scale, task, "full")

    if BBH_SCALE in scales:
        # LoraHub expert pool: experts for all pretrain-era rules.
        for task in T.pretrain_tasks()[: 12]:
            finetune_expert(BBH_SCALE, task, "lora")

    if RANK_SCALE in scales:
        for task in T.instruct_tasks()[:5]:
            for rank in EXTRA_LORA_RANKS:
                finetune_expert(RANK_SCALE, task, "lora", rank=rank)

    # Figure 3 PEFT zoo (trained in-python; sizes+accs recorded to JSON).
    from . import peft_zoo

    peft_zoo.build_figure3(scales)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scales", default=",".join(C.SCALE_ORDER))
    args = ap.parse_args()
    scales = [s for s in args.scales.split(",") if s]
    t0 = time.time()
    build_all(scales)
    _log(f"artifacts complete in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
