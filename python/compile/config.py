"""Global configuration for the µT model family and the artifact layout.

The µT ("micro-transformer") family substitutes for the paper's
LLaMA/T5/T0 bases (DESIGN.md §3): four decoder-only scales spanning
~100x in parameter count, trained on synthetic instruction-style tasks.
Everything downstream (Rust coordinator, benches) reads the artifact
paths defined here.
"""

import os
from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Vocabulary layout (fixed across all scales).
# ---------------------------------------------------------------------------
VOCAB = 256
PAD, BOS, QUERY, SEP = 0, 1, 2, 3
ANSWER_BASE = 10          # answer tokens: 10 .. 10+MAX_CLASSES-1
MAX_CLASSES = 8
DATA_LO, DATA_HI = 32, 96    # data tokens (64-value alphabet)
INSTR_LO, INSTR_HI = 200, 256  # instruction (task-id) tokens

SEQ_LEN = 18   # [BOS, instr, 14 data tokens, QUERY, answer]
N_DATA = SEQ_LEN - 4
ANSWER_POS = SEQ_LEN - 1     # answer token position
QUERY_POS = SEQ_LEN - 2      # logits at this position predict the answer


@dataclass(frozen=True)
class ModelConfig:
    """One µT scale."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    lora_rank: int = 8

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


SCALES = {
    "xs": ModelConfig("xs", d_model=32, n_layers=2, n_heads=2, d_ff=128, lora_rank=4),
    "s": ModelConfig("s", d_model=64, n_layers=3, n_heads=4, d_ff=256, lora_rank=8),
    "m": ModelConfig("m", d_model=128, n_layers=4, n_heads=4, d_ff=512, lora_rank=8),
    "l": ModelConfig("l", d_model=160, n_layers=5, n_heads=8, d_ff=640, lora_rank=16),
}

SCALE_ORDER = ["xs", "s", "m", "l"]

# ---------------------------------------------------------------------------
# Training presets. `full` is the default for `make artifacts`; `ci` keeps
# pytest fast. Override with COMPEFT_TRAIN_PRESET=ci.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainPreset:
    pretrain_steps: int           # default; see PRETRAIN_STEPS per scale
    finetune_steps: int
    batch_size: int
    eval_examples: int        # per eval set
    fewshot_examples: int     # for LoraHub few-shot objectives
    lr_pretrain: float = 3e-3
    lr_lora: float = 2e-3
    lr_ia3: float = 5e-3
    lr_full: float = 5e-4
    pretrain_batch: int = 64


PRESETS = {
    "full": TrainPreset(
        pretrain_steps=1800,
        finetune_steps=150,
        batch_size=32,
        eval_examples=400,
        fewshot_examples=32,
        lr_pretrain=2e-3,
    ),
    "ci": TrainPreset(
        pretrain_steps=60,
        finetune_steps=20,
        batch_size=16,
        eval_examples=40,
        fewshot_examples=8,
    ),
}


# Larger scales learn more per example; fewer steps keeps the single-core
# build bounded while preserving the zero-shot-quality-vs-scale trend.
PRETRAIN_STEPS = {"xs": 2400, "s": 1800, "m": 1400, "l": 1100}


def preset() -> TrainPreset:
    return PRESETS[os.environ.get("COMPEFT_TRAIN_PRESET", "full")]


def pretrain_steps(scale: str) -> int:
    if os.environ.get("COMPEFT_TRAIN_PRESET", "full") == "ci":
        return preset().pretrain_steps
    return PRETRAIN_STEPS.get(scale, preset().pretrain_steps)


# ---------------------------------------------------------------------------
# Artifact layout.
# ---------------------------------------------------------------------------

def artifacts_dir() -> str:
    return os.environ.get(
        "COMPEFT_ARTIFACTS",
        os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
    )


def model_dir(scale: str) -> str:
    return os.path.join(artifacts_dir(), "models", scale)


def experts_dir(scale: str) -> str:
    return os.path.join(artifacts_dir(), "experts", scale)


def eval_dir() -> str:
    return os.path.join(artifacts_dir(), "eval")


def kernels_dir() -> str:
    return os.path.join(artifacts_dir(), "kernels")
