"""The Figure-3 PEFT method zoo.

Ten parameter-efficient fine-tuning methods trained on µT for the
storage-vs-performance Pareto study (paper §3.5 / Figure 3):

  full, LoRA, (IA)3, LayerNorm, BitFit, Adapters (Houlsby),
  Compacter (Kronecker-factorized adapters), Prompt Tuning,
  Prefix Tuning, Intrinsic-SAID.

Each method is expressed as an *adapter hook* on a shared zoo forward
pass so accuracies are comparable. Sizes are the exact fp16 bytes of
each method's trainable parameters; ComLoRA / Com(IA)3 points are added
by the Rust bench from the compressed expert artifacts.

Build-time only (results → artifacts/figure3.json).
"""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import config as C
from . import model as M
from . import tasks as T

N_PROMPT = 8      # prompt-tuning virtual tokens
N_PREFIX = 4      # prefix-tuning kv positions per layer
ADAPTER_BOTTLENECK = 8
COMPACTER_N = 4   # kronecker factor edge
SAID_DIM = 64     # intrinsic dimensionality


# ---------------------------------------------------------------------------
# Adapter initialization per method
# ---------------------------------------------------------------------------


def init_zoo_params(method: str, cfg, base: dict, seed: int = 0):
    """Returns (trainable_params, consts) for a zoo method."""
    rng = np.random.default_rng(seed + 31)
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers

    def norm(shape, scale):
        return jnp.asarray(rng.normal(0, scale, size=shape).astype(np.float32))

    if method == "full":
        return dict(base), {}
    if method == "lora":
        return M.init_lora_params(cfg, seed=seed), {}
    if method == "ia3":
        return M.init_ia3_params(cfg), {}
    if method == "layernorm":
        keys = [k for k in base if ".ln1" in k or ".ln2" in k or k == "ln_f"]
        return {k: base[k] for k in keys}, {}
    if method == "bitfit":
        p = {}
        for i in range(L):
            p[f"layers.{i}.bias.attn"] = jnp.zeros((d,), jnp.float32)
            p[f"layers.{i}.bias.mlp"] = jnp.zeros((d,), jnp.float32)
        p["bias.final"] = jnp.zeros((d,), jnp.float32)
        return p, {}
    if method == "adapter":
        b = ADAPTER_BOTTLENECK
        p = {}
        for i in range(L):
            p[f"layers.{i}.adpt.down"] = norm((d, b), d**-0.5)
            p[f"layers.{i}.adpt.up"] = jnp.zeros((b, d), jnp.float32)
        return p, {}
    if method == "compacter":
        # weight = kron(s, w): s is n x n shared-shape factor per layer,
        # w is (d/n) x (b/n)… we use kron(s [n,n], w [d/n, b]) -> (d, n*b)
        # then slice to (d, b); up analogous. Tiny parameter count.
        n = COMPACTER_N
        b = ADAPTER_BOTTLENECK
        p = {}
        for i in range(L):
            p[f"layers.{i}.cpt.s_down"] = norm((n, n), 0.5)
            p[f"layers.{i}.cpt.w_down"] = norm((d // n, b), d**-0.5)
            p[f"layers.{i}.cpt.s_up"] = jnp.zeros((n, n), jnp.float32)
            p[f"layers.{i}.cpt.w_up"] = norm((b // min(b, n), d // n), b**-0.5)
        return p, {}
    if method == "prompt":
        return {"prompt": norm((N_PROMPT, d), 0.02)}, {}
    if method == "prefix":
        p = {}
        for i in range(L):
            p[f"layers.{i}.prefix.k"] = norm((N_PREFIX, d), 0.02)
            p[f"layers.{i}.prefix.v"] = norm((N_PREFIX, d), 0.02)
        return p, {}
    if method == "said":
        # Fixed random unit directions, one per parameter tensor chunk;
        # trainable z scales them (Aghajanyan et al., 2020).
        names = M.export_order(base)
        dirs = {}
        for k in names:
            v = rng.normal(size=base[k].shape).astype(np.float32)
            v /= np.linalg.norm(v.reshape(-1)) + 1e-8
            dirs[k] = jnp.asarray(v)
        # SAID_DIM scalars spread round-robin across tensors.
        assign = {k: i % SAID_DIM for i, k in enumerate(names)}
        return {"z": jnp.zeros((SAID_DIM,), jnp.float32)}, {
            "dirs": dirs,
            "assign": assign,
        }
    raise ValueError(f"unknown zoo method {method!r}")


ZOO_METHODS = [
    "full",
    "lora",
    "ia3",
    "layernorm",
    "bitfit",
    "adapter",
    "compacter",
    "prompt",
    "prefix",
    "said",
]


# ---------------------------------------------------------------------------
# Shared zoo forward
# ---------------------------------------------------------------------------


def zoo_forward(cfg, base, tokens, method, p, consts):
    """Forward pass with the method's adapter hook applied."""
    if method == "full":
        return M.forward(cfg, p, tokens)
    if method == "lora":
        return M.forward(cfg, base, tokens, lora=p)
    if method == "ia3":
        return M.forward(cfg, base, tokens, ia3=p)
    if method in ("layernorm", "said"):
        merged = dict(base)
        if method == "layernorm":
            merged.update(p)
        else:
            dirs, assign = consts["dirs"], consts["assign"]
            for k in merged:
                merged[k] = merged[k] + p["z"][assign[k]] * dirs[k]
        return M.forward(cfg, merged, tokens)

    # Methods needing a custom block walk.
    x = base["embed"][tokens] + base["pos"][None, : tokens.shape[1]]
    query_pos = C.QUERY_POS
    if method == "prompt":
        b = x.shape[0]
        prm = jnp.broadcast_to(p["prompt"][None], (b,) + p["prompt"].shape)
        x = jnp.concatenate([prm, x], axis=1)
        query_pos = C.QUERY_POS + N_PROMPT

    for i in range(cfg.n_layers):
        pre = f"layers.{i}"
        hn = M._rmsnorm(x, base[f"{pre}.ln1"])
        q = hn @ base[f"{pre}.attn.wq"]
        k = hn @ base[f"{pre}.attn.wk"]
        v = hn @ base[f"{pre}.attn.wv"]
        if method == "prefix":
            att = _prefix_attention(
                cfg, q, k, v, p[f"{pre}.prefix.k"], p[f"{pre}.prefix.v"]
            )
        else:
            att = M._attention(cfg, q, k, v)
        x = x + att @ base[f"{pre}.attn.wo"]
        if method == "bitfit":
            x = x + p[f"{pre}.bias.attn"]

        hn = M._rmsnorm(x, base[f"{pre}.ln2"])
        hmid = jax.nn.gelu(hn @ base[f"{pre}.mlp.w1"])
        x = x + hmid @ base[f"{pre}.mlp.w2"]
        if method == "bitfit":
            x = x + p[f"{pre}.bias.mlp"]
        if method == "adapter":
            x = x + jax.nn.gelu(hn @ p[f"{pre}.adpt.down"]) @ p[f"{pre}.adpt.up"]
        if method == "compacter":
            down = jnp.kron(p[f"{pre}.cpt.s_down"], p[f"{pre}.cpt.w_down"])[
                : cfg.d_model, :ADAPTER_BOTTLENECK
            ]
            up = jnp.kron(p[f"{pre}.cpt.s_up"], p[f"{pre}.cpt.w_up"])[
                :ADAPTER_BOTTLENECK, : cfg.d_model
            ]
            x = x + jax.nn.gelu(hn @ down) @ up

    if method == "bitfit":
        x = x + p["bias.final"]
    x = M._rmsnorm(x, base["ln_f"])
    logits = x @ base["embed"].T
    return logits[:, query_pos, :]


def _prefix_attention(cfg, q, k, v, pk, pv):
    """Attention with learnable kv prefix (visible to every position)."""
    b, s, d = q.shape
    h, hd = cfg.n_heads, cfg.head_dim
    npfx = pk.shape[0]

    def split(t):
        return t.reshape(t.shape[0], t.shape[1], h, hd).transpose(0, 2, 1, 3)

    kfull = jnp.concatenate([jnp.broadcast_to(pk[None], (b, npfx, d)), k], axis=1)
    vfull = jnp.concatenate([jnp.broadcast_to(pv[None], (b, npfx, d)), v], axis=1)
    qh, kh, vh = split(q), split(kfull), split(vfull)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((s, s), bool))
    mask = jnp.concatenate([jnp.ones((s, npfx), bool), causal], axis=1)
    scores = jnp.where(mask[None, None], scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return out.transpose(0, 2, 1, 3).reshape(b, s, d)


# ---------------------------------------------------------------------------
# Training + Figure 3 build
# ---------------------------------------------------------------------------

ZOO_SCALE = "s"
ZOO_TASKS = 4     # first N instruct tasks
ZOO_LRS = {
    "full": 5e-4,
    "lora": 2e-3,
    "ia3": 5e-3,
    "layernorm": 5e-3,
    "bitfit": 5e-3,
    "adapter": 2e-3,
    "compacter": 2e-3,
    "prompt": 5e-3,
    "prefix": 2e-3,
    "said": 1e-1,
}


def train_zoo_method(cfg, base, task, method, steps, batch, seed=0):
    p, consts = init_zoo_params(method, cfg, base, seed)
    rng = np.random.default_rng(seed + 41)

    @jax.jit
    def step(p, opt, tokens, answers):
        def loss(q):
            logits = zoo_forward(cfg, base, tokens, method, q, consts)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, answers[:, None], 1))

        lval, grads = jax.value_and_grad(loss)(p)
        p, opt = M.adam_update(p, grads, opt, ZOO_LRS[method])
        return p, opt, lval

    opt = M.adam_init(p)
    for _ in range(steps):
        tokens, labels = task.generate(rng, batch)
        p, opt, _ = step(p, opt, jnp.asarray(tokens),
                         jnp.asarray(C.ANSWER_BASE + labels))
    return p, consts


def eval_zoo(cfg, base, task, method, p, consts, n, seed=1234) -> float:
    rng = np.random.default_rng(seed)
    tokens, labels = task.generate(rng, n)
    logits = zoo_forward(cfg, base, jnp.asarray(tokens), method, p, consts)
    return M.rank_accuracy(logits, jnp.asarray(labels), task.n_classes)


def method_bytes_fp16(p: dict) -> int:
    return int(sum(int(np.prod(v.shape)) for v in p.values()) * 2)


def build_figure3(scales) -> None:
    from .train import pretrain

    if ZOO_SCALE not in scales:
        return
    out_path = os.path.join(C.artifacts_dir(), "figure3.json")
    if os.path.exists(out_path):
        return
    pre = C.preset()
    cfg = C.SCALES[ZOO_SCALE]
    base = pretrain(ZOO_SCALE)
    tasks = T.instruct_tasks()[:ZOO_TASKS]
    results = {}
    for method in ZOO_METHODS:
        t0 = time.time()
        accs = []
        size = None
        for task in tasks:
            p, consts = init_zoo_params(method, cfg, base, 0)
            size = method_bytes_fp16(p)
            p, consts = train_zoo_method(
                cfg, base, task, method, pre.finetune_steps, pre.batch_size
            )
            accs.append(eval_zoo(cfg, base, task, method, p, consts,
                                 pre.eval_examples))
        results[method] = {
            "acc_mean": float(np.mean(accs)),
            "acc_per_task": [float(a) for a in accs],
            "bytes_fp16": size,
            "train_seconds": round(time.time() - t0, 1),
        }
        print(f"[zoo] {method}: acc {np.mean(accs):.3f} "
              f"size {size}B ({time.time()-t0:.0f}s)", flush=True)
    with open(out_path, "w") as f:
        json.dump({"scale": ZOO_SCALE, "methods": results}, f, indent=1)
