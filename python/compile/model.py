"""L2: the µT decoder-only transformer family with PEFT adapters.

Pure-functional JAX: parameters are flat ``dict[str, jnp.ndarray]`` with
canonical dotted names; the AOT exporter fixes the executable input
order as ``sorted(names)`` so the Rust runtime can marshal positionally.

Adapters:
  * LoRA  (Hu et al., 2021)   — low-rank deltas on wq/wv
  * (IA)3 (Liu et al., 2022)  — learned rescaling of k, v, and FFN
  * full fine-tuning          — all base parameters trainable
  * the Figure 3 PEFT zoo     — see :mod:`compile.peft_zoo`

The compressed serving path variant of :func:`forward` applies the LoRA
delta from ComPEFT mask pairs via the L1 Pallas kernel
(:mod:`compile.kernels.ternary_apply`), so the whole three-layer stack
lowers into one HLO module.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import config as C
from .config import ModelConfig
from .kernels.ternary_apply import ternary_matmul

# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def init_base_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Initialize base-model parameters (scaled normals)."""
    rng = np.random.default_rng(seed)
    p = {}

    def norm(shape, scale):
        return jnp.asarray(rng.normal(0, scale, size=shape).astype(np.float32))

    d, ff = cfg.d_model, cfg.d_ff
    p["embed"] = norm((C.VOCAB, d), 0.02)
    p["pos"] = norm((C.SEQ_LEN, d), 0.02)
    for i in range(cfg.n_layers):
        pre = f"layers.{i}"
        p[f"{pre}.ln1"] = jnp.ones((d,), jnp.float32)
        p[f"{pre}.ln2"] = jnp.ones((d,), jnp.float32)
        for w in ["wq", "wk", "wv", "wo"]:
            p[f"{pre}.attn.{w}"] = norm((d, d), d**-0.5)
        p[f"{pre}.mlp.w1"] = norm((d, ff), d**-0.5)
        p[f"{pre}.mlp.w2"] = norm((ff, d), ff**-0.5)
    p["ln_f"] = jnp.ones((d,), jnp.float32)
    return p


def init_lora_params(cfg: ModelConfig, seed: int = 0, rank: int | None = None) -> dict:
    """LoRA A (gaussian) / B (zero) for wq and wv of every layer.

    B = 0 makes the initial delta exactly zero, so the LoRA task vector
    is θ_ft − θ_init over these tensors.
    """
    rng = np.random.default_rng(seed + 7)
    p = {}
    d = cfg.d_model
    r = rank or cfg.lora_rank
    for i in range(cfg.n_layers):
        for w in ["wq", "wv"]:
            pre = f"layers.{i}.attn.{w}"
            p[f"{pre}.lora_a"] = jnp.asarray(
                rng.normal(0, r**-0.5, size=(d, r)).astype(np.float32)
            )
            p[f"{pre}.lora_b"] = jnp.zeros((r, d), jnp.float32)
    return p


def init_ia3_params(cfg: ModelConfig) -> dict:
    """(IA)3 rescaling vectors, initialized to 1 (identity)."""
    p = {}
    for i in range(cfg.n_layers):
        pre = f"layers.{i}.ia3"
        p[f"{pre}.k"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[f"{pre}.v"] = jnp.ones((cfg.d_model,), jnp.float32)
        p[f"{pre}.ff"] = jnp.ones((cfg.d_ff,), jnp.float32)
    return p


def param_count(params: dict) -> int:
    return int(sum(int(np.prod(v.shape)) for v in params.values()))


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _rmsnorm(x, w):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * w


def _attention(cfg: ModelConfig, q, k, v):
    b, s, d = q.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def split(t):
        return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return out.transpose(0, 2, 1, 3).reshape(b, s, d)


LORA_SCALE = 2.0  # alpha/r with alpha = 2r


def forward(
    cfg: ModelConfig,
    base: dict,
    tokens,
    lora: dict | None = None,
    ia3: dict | None = None,
    lora_ternary: dict | None = None,
):
    """Logits over the vocab at the QUERY position: [B, VOCAB].

    ``lora_ternary`` carries a ComPEFT-compressed LoRA delta as mask
    pairs: ``{tensor_name: (pos_mask, neg_mask, scale)}`` applied with
    the Pallas ternary-matmul kernel on top of the base projection.
    """
    x = base["embed"][tokens] + base["pos"][None, : tokens.shape[1]]

    def proj(h, name):
        w = base[name]
        y = h @ w
        if lora is not None and f"{name}.lora_a" in lora:
            a, bm = lora[f"{name}.lora_a"], lora[f"{name}.lora_b"]
            y = y + LORA_SCALE * ((h @ a) @ bm)
        if lora_ternary is not None and name in lora_ternary:
            pos, neg, scale = lora_ternary[name]
            b_, s_, d_ = h.shape
            flat = h.reshape(b_ * s_, d_)
            y = y + ternary_matmul(flat, pos, neg, scale).reshape(y.shape)
        return y

    for i in range(cfg.n_layers):
        pre = f"layers.{i}"
        hn = _rmsnorm(x, base[f"{pre}.ln1"])
        q = proj(hn, f"{pre}.attn.wq")
        k = hn @ base[f"{pre}.attn.wk"]
        v = proj(hn, f"{pre}.attn.wv")
        if ia3 is not None:
            k = k * ia3[f"{pre}.ia3.k"]
            v = v * ia3[f"{pre}.ia3.v"]
        att = _attention(cfg, q, k, v)
        x = x + att @ base[f"{pre}.attn.wo"]

        hn = _rmsnorm(x, base[f"{pre}.ln2"])
        hmid = jax.nn.gelu(hn @ base[f"{pre}.mlp.w1"])
        if ia3 is not None:
            hmid = hmid * ia3[f"{pre}.ia3.ff"]
        x = x + hmid @ base[f"{pre}.mlp.w2"]

    x = _rmsnorm(x, base["ln_f"])
    logits = x @ base["embed"].T  # tied unembedding
    return logits[:, C.QUERY_POS, :]


# ---------------------------------------------------------------------------
# Loss / accuracy
# ---------------------------------------------------------------------------


def loss_fn(cfg, base, tokens, answer_tokens, lora=None, ia3=None):
    """Cross-entropy of the answer token at the QUERY position."""
    logits = forward(cfg, base, tokens, lora=lora, ia3=ia3)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, answer_tokens[:, None], axis=1))


def rank_accuracy(logits, labels, n_classes) -> float:
    """Rank classification over the answer-token candidates (paper
    B.1): prediction = argmax over the C candidate answer tokens."""
    cands = logits[:, C.ANSWER_BASE : C.ANSWER_BASE + n_classes]
    return float(jnp.mean(jnp.argmax(cands, axis=-1) == labels))


# ---------------------------------------------------------------------------
# Adam (optax is unavailable offline)
# ---------------------------------------------------------------------------


def adam_init(params: dict) -> dict:
    return {
        "m": {k: jnp.zeros_like(v) for k, v in params.items()},
        "v": {k: jnp.zeros_like(v) for k, v in params.items()},
        "t": jnp.zeros((), jnp.int32),
    }


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    tf = t.astype(jnp.float32)
    new = {}
    for k in params:
        mh = m[k] / (1 - b1**tf)
        vh = v[k] / (1 - b2**tf)
        new[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Canonical export order
# ---------------------------------------------------------------------------


def export_order(params: dict) -> list[str]:
    """The positional input order used by every AOT executable."""
    return sorted(params.keys())


def params_to_list(params: dict) -> list:
    return [params[k] for k in export_order(params)]
