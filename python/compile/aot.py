"""AOT lowering: JAX → HLO **text** artifacts for the Rust runtime.

HLO text (NOT ``lowered.serialize()``) is the interchange format: the
image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit
instruction ids); the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Per scale we export:

  forward_b{B}.hlo.txt        logits(params…, tokens[B,S]) — base model
  forward_lora_b{B}.hlo.txt   logits(params…, lora…, tokens[B,S])
  forward_ia3_b{B}.hlo.txt    logits(params…, ia3…, tokens[B,S])

plus the standalone L1 kernel artifacts:

  kernels/ternarize.hlo.txt       Pallas topk_ternary elementwise pass
  kernels/ternary_apply.hlo.txt   Pallas mask-pair matmul

Input order for every executable is ``sorted(param_names)`` then any
adapter names (sorted) then ``tokens`` — recorded in each scale's
``meta.json`` by train.py and relied on by ``rust/src/runtime``.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import config as C
from . import model as M

EVAL_BATCH = 64
SERVE_BATCH = 8
BATCHES = (SERVE_BATCH, EVAL_BATCH)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (xla-example recipe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {path} ({len(text)} chars)", flush=True)


def _spec(arr) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(arr.shape, arr.dtype)


def export_forward(scale: str, kind: str, batch: int) -> None:
    """Lower one forward variant to HLO text. `kind` in {base,lora,ia3}."""
    cfg = C.SCALES[scale]
    suffix = "" if kind == "base" else f"_{kind}"
    out = os.path.join(C.model_dir(scale), f"forward{suffix}_b{batch}.hlo.txt")
    if os.path.exists(out):
        return

    base = M.init_base_params(cfg)
    base_order = M.export_order(base)
    tokens = jnp.zeros((batch, C.SEQ_LEN), jnp.int32)

    if kind == "base":

        def fn(*args):
            p = dict(zip(base_order, args[: len(base_order)]))
            return (M.forward(cfg, p, args[-1]),)

        specs = [_spec(base[k]) for k in base_order] + [_spec(tokens)]
    elif kind == "lora":
        lora = M.init_lora_params(cfg)
        lora_order = M.export_order(lora)

        def fn(*args):
            p = dict(zip(base_order, args[: len(base_order)]))
            la = dict(
                zip(lora_order, args[len(base_order) : len(base_order) + len(lora_order)])
            )
            return (M.forward(cfg, p, args[-1], lora=la),)

        specs = (
            [_spec(base[k]) for k in base_order]
            + [_spec(lora[k]) for k in lora_order]
            + [_spec(tokens)]
        )
    elif kind == "ia3":
        ia3 = M.init_ia3_params(cfg)
        ia3_order = M.export_order(ia3)

        def fn(*args):
            p = dict(zip(base_order, args[: len(base_order)]))
            a = dict(
                zip(ia3_order, args[len(base_order) : len(base_order) + len(ia3_order)])
            )
            return (M.forward(cfg, p, args[-1], ia3=a),)

        specs = (
            [_spec(base[k]) for k in base_order]
            + [_spec(ia3[k]) for k in ia3_order]
            + [_spec(tokens)]
        )
    else:
        raise ValueError(kind)

    lowered = jax.jit(fn).lower(*specs)
    _write(out, to_hlo_text(lowered))


def export_kernels() -> None:
    """Standalone L1 kernel artifacts (loaded by runtime tests/benches)."""
    from .kernels.ternary_apply import ternary_matmul
    from .kernels.topk_ternary import ternarize

    kd = C.kernels_dir()

    out = os.path.join(kd, "ternarize.hlo.txt")
    if not os.path.exists(out):
        n = 1 << 16

        def fn(tau, thr, scale):
            return (ternarize(tau, thr, scale),)

        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )
        _write(out, to_hlo_text(lowered))

    out = os.path.join(kd, "ternary_apply.hlo.txt")
    if not os.path.exists(out):
        m, k, n = 32, 256, 256

        def fn(x, pos, neg, scale):
            return (ternary_matmul(x, pos, neg, scale),)

        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )
        _write(out, to_hlo_text(lowered))


def export_all(scales) -> None:
    export_kernels()
    for scale in scales:
        for batch in BATCHES:
            export_forward(scale, "base", batch)
            export_forward(scale, "lora", batch)
            export_forward(scale, "ia3", batch)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scales", default=",".join(C.SCALE_ORDER))
    ap.add_argument("--skip-train", action="store_true",
                    help="only lower HLO (assume experts already built)")
    args = ap.parse_args()
    scales = [s for s in args.scales.split(",") if s]

    if not args.skip_train:
        from . import train

        train.build_all(scales)
    export_all(scales)
    print("[aot] all artifacts ready", flush=True)


if __name__ == "__main__":
    main()
