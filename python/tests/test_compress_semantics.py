"""Algorithm 1 semantics: python oracle vs paper formulas.

The Rust implementation is the production compressor; this cross-checks
the shared semantics (σ scaling, top-k support, ternary levels) on the
jnp oracle so the two sides cannot silently drift. Entropy accounting is
validated against the paper's §2.2 closed forms.
"""

import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import ref_compress


def test_compressed_support_is_topk():
    rng = np.random.default_rng(0)
    tau = rng.normal(size=(2000,)).astype(np.float32)
    out = np.asarray(ref_compress(jnp.asarray(tau), 0.1, 1.0))
    nz = out != 0
    kept = np.abs(tau)[nz].min()
    dropped = np.abs(tau)[~nz].max()
    assert kept >= dropped - 1e-6
    assert nz.sum() >= 200


def test_signs_match_original():
    rng = np.random.default_rng(1)
    tau = rng.normal(size=(512,)).astype(np.float32)
    out = np.asarray(ref_compress(jnp.asarray(tau), 0.3, 2.0))
    nz = out != 0
    np.testing.assert_array_equal(np.sign(out[nz]), np.sign(tau[nz]))


def test_entropy_formula_matches_paper():
    # H = -((1-k)log2(1-k) + k log2(k/2)) * d + 16; at k=0.05 ≈ 0.34/param
    k, d = 0.05, 1_000_000
    h = -((1 - k) * np.log2(1 - k) + k * np.log2(k / 2)) * d + 16
    per_param = h / d
    assert abs(per_param - 0.3382) < 5e-3
    assert abs(16 * d / h - 47.0) < 1.5  # ~47x claim


def test_golomb_bstar_formula():
    # b* = 1 + floor(log2(log(phi-1)/log(1-p))); p=0.05 -> 5-ish
    phi = (np.sqrt(5) + 1) / 2
    for p, expect_range in [(0.05, (4, 6)), (0.3, (0, 2))]:
        b = 1 + np.floor(np.log2(np.log(phi - 1) / np.log(1 - p)))
        assert expect_range[0] <= b <= expect_range[1]
