"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and value distributions; every case must match
the oracle to float tolerance. This is the build-time gate required
before any HLO artifact is trusted (DESIGN.md §2).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    ref_compress,
    ref_ternarize,
    ref_ternary_matmul,
    ref_topk_threshold,
)
from compile.kernels.ternary_apply import ternary_matmul
from compile.kernels.topk_ternary import ternarize

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    n=st.integers(1, 5000),
    thr=st.floats(0.0, 3.0),
    scale=st.floats(-4.0, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_ternarize_matches_ref(n, thr, scale, seed):
    rng = np.random.default_rng(seed)
    tau = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    out = ternarize(tau, thr, scale)
    ref = ref_ternarize(tau, thr, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@given(
    shape=st.sampled_from([(7,), (128,), (3, 5), (64, 33), (2, 3, 4)]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_ternarize_arbitrary_shapes(shape, seed):
    rng = np.random.default_rng(seed)
    tau = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    out = ternarize(tau, 0.5, 1.5)
    ref = ref_ternarize(tau, 0.5, 1.5)
    assert out.shape == tau.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_ternarize_zero_stays_zero():
    tau = jnp.zeros((300,), jnp.float32)
    out = ternarize(tau, 0.0, 7.0)
    # sign(0) == 0: threshold 0 keeps everything but zeros emit zero.
    np.testing.assert_allclose(np.asarray(out), 0.0)


@given(
    density=st.sampled_from([0.05, 0.1, 0.2, 0.5, 1.0]),
    alpha=st.sampled_from([0.5, 1.0, 2.0, 4.0]),
    n=st.integers(10, 3000),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_compress_pallas_matches_ref(density, alpha, n, seed):
    from compile.kernels.topk_ternary import compress_pallas

    rng = np.random.default_rng(seed)
    tau = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    out = compress_pallas(tau, density, alpha)
    ref = ref_compress(tau, density, alpha)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    dens=st.floats(0.0, 0.3),
    scale=st.floats(-2.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_ternary_matmul_matches_ref(m, k, n, dens, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    pos = (rng.random((k, n)) < dens).astype(np.float32)
    neg = ((rng.random((k, n)) < dens) * (1 - pos)).astype(np.float32)
    pos, neg = jnp.asarray(pos), jnp.asarray(neg)
    out = ternary_matmul(x, pos, neg, scale)
    ref = ref_ternary_matmul(x, pos, neg, scale)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4
    )


def test_ternary_matmul_exact_tile_shapes():
    # No-padding path: shapes already multiples of 128.
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128, 256)).astype(np.float32))
    pos = jnp.asarray((rng.random((256, 128)) < 0.1).astype(np.float32))
    neg = jnp.zeros((256, 128), jnp.float32)
    out = ternary_matmul(x, pos, neg, 2.0)
    ref = ref_ternary_matmul(x, pos, neg, 2.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_topk_threshold_keeps_at_least_expected():
    rng = np.random.default_rng(1)
    tau = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    for k in [0.05, 0.2, 0.5]:
        thr = ref_topk_threshold(tau, k)
        kept = int(jnp.sum(jnp.abs(tau) >= thr))
        assert kept >= int(np.ceil(k * 1000))
        # Not wildly more (ties only).
        assert kept <= int(np.ceil(k * 1000)) + 5


@pytest.mark.parametrize("density", [0.05, 0.2])
def test_compress_scale_is_alpha_sigma(density):
    rng = np.random.default_rng(2)
    tau = jnp.asarray(rng.normal(size=(4096,)).astype(np.float32))
    out = np.asarray(ref_compress(tau, density, 3.0))
    nz = out[out != 0]
    sigma = float(jnp.std(tau))
    np.testing.assert_allclose(np.abs(nz), 3.0 * sigma, rtol=1e-5)
