"""Task-suite invariants: determinism, label ranges, benchmark layout."""

import numpy as np
import pytest

import compile.config as C
import compile.tasks as T


def all_suites():
    return {
        "pretrain": T.pretrain_tasks(),
        "instruct": T.instruct_tasks(),
        "glue": T.glue_tasks(),
        "bbh": T.bbh_tasks(),
    }


def test_suite_sizes():
    s = all_suites()
    assert len(s["pretrain"]) == T.N_PRETRAIN_RULES
    assert len(s["instruct"]) == 8
    assert len(s["glue"]) == 7
    assert len(s["bbh"]) == T.N_BBH
    assert len(T.heldout_bench_tasks()) == T.N_HELDOUT_BENCH


def test_instruction_tokens_unique_and_in_range():
    seen = set()
    for suite in all_suites().values():
        for t in suite:
            assert C.INSTR_LO <= t.instr_token < C.INSTR_HI, t.name
            assert t.instr_token not in seen, f"duplicate instr for {t.name}"
            seen.add(t.instr_token)


def test_generation_deterministic():
    t = T.instruct_tasks()[0]
    a = t.generate(np.random.default_rng(5), 16)
    b = t.generate(np.random.default_rng(5), 16)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


@pytest.mark.parametrize("suite", ["pretrain", "instruct", "glue", "bbh"])
def test_sequences_well_formed(suite):
    rng = np.random.default_rng(0)
    for t in all_suites()[suite]:
        tokens, labels = t.generate(rng, 64)
        assert tokens.shape == (64, C.SEQ_LEN)
        assert labels.min() >= 0 and labels.max() < t.n_classes
        assert (tokens[:, 0] == C.BOS).all()
        assert (tokens[:, 1] == t.instr_token).all()
        assert (tokens[:, C.QUERY_POS] == C.QUERY).all()
        # Answer token encodes the label.
        np.testing.assert_array_equal(
            tokens[:, C.ANSWER_POS], C.ANSWER_BASE + labels
        )
        # Data tokens in the data alphabet.
        data = tokens[:, 2 : 2 + C.N_DATA]
        assert data.min() >= C.DATA_LO and data.max() < C.DATA_HI


def test_labels_not_degenerate():
    """Each task has both/most classes represented (no constant task)."""
    rng = np.random.default_rng(1)
    for t in T.pretrain_tasks() + T.instruct_tasks() + T.glue_tasks():
        _, labels = t.generate(rng, 400)
        counts = np.bincount(labels, minlength=t.n_classes)
        assert (counts > 10).sum() >= 2, f"{t.name} degenerate: {counts}"


def test_rules_learnable_by_linear_probe():
    """Sanity: the label must be computable from the two rule positions —
    a decision stump on the relevant positions beats chance by a margin."""
    rng = np.random.default_rng(2)
    t = T.instruct_tasks()[0]
    tokens, labels = t.generate(rng, 2000)
    # Perfect recomputation via the family function:
    data = tokens[:, 2 : 2 + C.N_DATA]
    relabel = T._apply_family(t.family, t.rule, data)
    perm = np.asarray(t.rule["answer_perm"])
    np.testing.assert_array_equal(perm[relabel], labels)


def test_mixture_covers_all_tasks():
    rng = np.random.default_rng(3)
    suite = T.pretrain_tasks()
    tokens, labels, tid = T.generate_mixture(suite, rng, 256)
    assert tokens.shape[0] == 256
    assert len(np.unique(tid)) == len(suite)


def test_bbh_tasks_compose_binary_families():
    for t in T.bbh_tasks():
        assert t.family in ("compose_and", "compose_xor")
        assert t.n_classes == 2
        assert t.rule["fam_a"] in T._BINARY_FAMILIES
        assert t.rule["fam_b"] in T._BINARY_FAMILIES
