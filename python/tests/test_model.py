"""L2 model tests: shapes, adapters, training step, export order."""

import jax.numpy as jnp
import numpy as np
import pytest

import compile.config as C
import compile.model as M
import compile.tasks as T

CFG = C.SCALES["xs"]


@pytest.fixture(scope="module")
def base():
    return M.init_base_params(CFG, seed=0)


def tokens(n=4, seed=0):
    t, labels = T.pretrain_tasks()[0].generate(np.random.default_rng(seed), n)
    return jnp.asarray(t), jnp.asarray(labels)


def test_forward_shape(base):
    tok, _ = tokens(5)
    logits = M.forward(CFG, base, tok)
    assert logits.shape == (5, C.VOCAB)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_param_counts_scale_order():
    counts = [
        M.param_count(M.init_base_params(C.SCALES[s])) for s in C.SCALE_ORDER
    ]
    assert counts == sorted(counts)
    assert counts[-1] > 20 * counts[0], counts


def test_lora_zero_delta_at_init(base):
    """B=0 ⇒ LoRA init is an exact no-op (τ = θ_ft − θ_init is the
    entire behavioural change)."""
    tok, _ = tokens(3)
    plain = M.forward(CFG, base, tok)
    lora = M.init_lora_params(CFG)
    with_lora = M.forward(CFG, base, tok, lora=lora)
    np.testing.assert_allclose(
        np.asarray(plain), np.asarray(with_lora), atol=1e-6
    )


def test_ia3_identity_at_init(base):
    tok, _ = tokens(3)
    plain = M.forward(CFG, base, tok)
    ia3 = M.init_ia3_params(CFG)
    with_ia3 = M.forward(CFG, base, tok, ia3=ia3)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(with_ia3), atol=1e-6)


def test_nonzero_adapters_change_output(base):
    tok, _ = tokens(3)
    plain = M.forward(CFG, base, tok)
    lora = M.init_lora_params(CFG)
    lora = {k: (v + 0.05 if "lora_b" in k else v) for k, v in lora.items()}
    changed = M.forward(CFG, base, tok, lora=lora)
    assert not np.allclose(np.asarray(plain), np.asarray(changed))


def test_lora_ternary_path_matches_dense_delta(base):
    """The Pallas mask-pair path equals adding the equivalent dense
    ternary delta to the base weight — the three layers agree."""
    tok, _ = tokens(3)
    name = "layers.0.attn.wq"
    d = CFG.d_model
    rng = np.random.default_rng(7)
    pos = (rng.random((d, d)) < 0.05).astype(np.float32)
    neg = ((rng.random((d, d)) < 0.05) * (1 - pos)).astype(np.float32)
    scale = 0.02
    tern = {name: (jnp.asarray(pos), jnp.asarray(neg), scale)}
    out_kernel = M.forward(CFG, base, tok, lora_ternary=tern)

    dense = dict(base)
    dense[name] = base[name] + scale * (jnp.asarray(pos) - jnp.asarray(neg))
    out_dense = M.forward(CFG, dense, tok)
    np.testing.assert_allclose(
        np.asarray(out_kernel), np.asarray(out_dense), atol=1e-4, rtol=1e-4
    )


def test_loss_decreases_with_training(base):
    task = T.pretrain_tasks()[0]
    import jax

    params = dict(base)
    opt = M.adam_init(params)

    @jax.jit
    def step(p, o, tok, ans):
        l, g = jax.value_and_grad(lambda q: M.loss_fn(CFG, q, tok, ans))(p)
        p, o = M.adam_update(p, g, o, 3e-3)
        return p, o, l

    rng = np.random.default_rng(0)
    losses = []
    for _ in range(30):
        tok, labels = task.generate(rng, 16)
        params, opt, loss = step(
            params, opt, jnp.asarray(tok), jnp.asarray(C.ANSWER_BASE + labels)
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_rank_accuracy_protocol():
    logits = np.zeros((2, C.VOCAB), np.float32)
    logits[0, C.ANSWER_BASE + 1] = 5.0  # predicts class 1
    logits[1, C.ANSWER_BASE + 0] = 5.0  # predicts class 0
    acc = M.rank_accuracy(jnp.asarray(logits), jnp.asarray([1, 1]), 2)
    assert acc == 0.5


def test_export_order_is_sorted_and_stable(base):
    order = M.export_order(base)
    assert order == sorted(order)
    assert order == M.export_order(dict(reversed(list(base.items()))))


def test_adam_moves_toward_minimum():
    params = {"w": jnp.asarray([4.0, -2.0])}
    opt = M.adam_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, opt = M.adam_update(params, grads, opt, 0.05)
    np.testing.assert_allclose(np.asarray(params["w"]), 0.0, atol=0.05)
